"""Hierarchical (local → global) aggregation with OP-typed parameters
(paper §3.2, §4.2).

Users declare, per communicated entry, an aggregation OP:

  WEIGHTED_AVG — Σ w_m x_m / Σ w_m        (model params/deltas; FedAvg etc.)
  AVG          — simple mean over clients
  SUM          — Σ x_m                    (counters, control-variate deltas)
  COLLECT      — concatenated per-client values ("Special Params."; cannot be
                 reduced, comm size stays O(s_e · M_p) — paper §4.2)

The decomposition is exact: executors fold their clients into a running
partial (``LocalAggregator``), the server combines the K partials
(``global_aggregate``).  ``flat_aggregate`` is the reference original-FL
aggregation; tests assert bit-level agreement for the reducible OPs.

The fold's inner loop (fp32 ``acc += w · x`` over every model parameter for
every simulated client) is the memory-bound hot-spot of the whole simulator —
``use_kernel=True`` routes it through the Pallas ``agg_weighted_sum`` kernel.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Op(enum.Enum):
    WEIGHTED_AVG = "weighted_avg"
    AVG = "avg"
    SUM = "sum"
    COLLECT = "collect"


@dataclass(frozen=True)
class ClientResult:
    """What one simulated client returns to its executor.

    ``payload`` maps entry name -> pytree; ``ops`` maps entry name -> Op;
    ``weight`` is the client's aggregation weight (typically N_m).
    """
    payload: Dict[str, Any]
    ops: Dict[str, Op]
    weight: float
    metrics: Dict[str, float] = field(default_factory=dict)


def _fold_weighted(acc, x, w: float, use_kernel: bool):
    if use_kernel:
        from repro.kernels import ops as kops
        return jax.tree.map(lambda a, b: kops.agg_fold(a, b, w), acc, x)
    return jax.tree.map(
        lambda a, b: a + w * b.astype(jnp.float32), acc, x)


class LocalAggregator:
    """Per-executor running aggregate (``LocalAggregate`` in Algorithm 2).

    Memory is O(s_a) regardless of how many clients the executor simulates —
    this is the paper's memory claim for sequential training.
    """

    def __init__(self, ops: Dict[str, Op], use_kernel: bool = False):
        self.ops = dict(ops)
        self.use_kernel = use_kernel
        self._sums: Dict[str, Any] = {}
        self._weights: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}
        self._collected: Dict[str, List[Any]] = {}
        self.n_clients = 0

    def fold(self, result: ClientResult) -> None:
        self.n_clients += 1
        for name, value in result.payload.items():
            op = self.ops[name]
            if op is Op.COLLECT:
                self._collected.setdefault(name, []).append(
                    (result.weight, value))
                continue
            w = result.weight if op is Op.WEIGHTED_AVG else 1.0
            if name not in self._sums:
                self._sums[name] = jax.tree.map(
                    lambda a: jnp.zeros(a.shape, jnp.float32), value)
                self._weights[name] = 0.0
                self._counts[name] = 0
            if op is Op.SUM:
                self._sums[name] = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32),
                    self._sums[name], value)
            else:
                self._sums[name] = _fold_weighted(
                    self._sums[name], value, w, self.use_kernel)
            self._weights[name] += w
            self._counts[name] += 1

    def partial(self) -> Dict[str, Any]:
        """The G_k message sent to the server: one trip, O(s_a K) total."""
        return {
            "sums": self._sums,
            "weights": self._weights,
            "counts": self._counts,
            "collected": self._collected,
            "n_clients": self.n_clients,
        }


def global_aggregate(partials: List[Dict[str, Any]],
                     ops: Dict[str, Op]) -> Dict[str, Any]:
    """``GlobalAggregate`` in Algorithm 2: combine the K partials (K-1 sums
    at the server instead of M_p-1)."""
    out: Dict[str, Any] = {}
    for name, op in ops.items():
        if op is Op.COLLECT:
            coll: List[Any] = []
            for p in partials:
                coll.extend(p["collected"].get(name, []))
            out[name] = coll
            continue
        sums = [p["sums"][name] for p in partials if name in p["sums"]]
        if not sums:
            continue
        total = jax.tree.map(lambda *xs: sum(xs), *sums)
        if op is Op.SUM:
            out[name] = total
        elif op is Op.AVG:
            n = sum(p["counts"].get(name, 0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(n, 1), total)
        else:  # WEIGHTED_AVG
            wtot = sum(p["weights"].get(name, 0.0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(wtot, 1e-12), total)
    return out


def flat_aggregate(results: List[ClientResult],
                   ops: Dict[str, Op]) -> Dict[str, Any]:
    """Reference original-FL aggregation (server folds every client) used to
    verify exactness of the hierarchical scheme."""
    agg = LocalAggregator(ops)
    for r in results:
        agg.fold(r)
    return global_aggregate([agg.partial()], ops)


def payload_bytes(tree: Any) -> int:
    total = 0
    for a in jax.tree.leaves(tree):
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            total += int(np.prod(a.shape)) * a.dtype.itemsize
        elif isinstance(a, (int, float, bool)):
            total += 8
    return total
