from repro.data.partition import (dirichlet_label_partition, natural_sizes,
                                  partition_sizes, quantity_skew_sizes)
from repro.data.synthetic import make_classification_clients, make_lm_clients

__all__ = [
    "dirichlet_label_partition", "natural_sizes", "partition_sizes",
    "quantity_skew_sizes", "make_classification_clients", "make_lm_clients",
]
