from repro.data.partition import (dirichlet_label_partition, natural_sizes,
                                  partition_sizes, quantity_skew_sizes)
from repro.data.synthetic import (make_classification_clients,
                                  make_classification_population,
                                  make_lm_clients)
from repro.data.traces import (BehaviorRow, CapacityRow, load_behavior_trace,
                               load_capacity_trace, save_behavior_trace,
                               save_capacity_trace, synthesize_behavior_trace,
                               synthesize_capacity_trace)

__all__ = [
    "dirichlet_label_partition", "natural_sizes", "partition_sizes",
    "quantity_skew_sizes", "make_classification_clients",
    "make_classification_population", "make_lm_clients",
    "BehaviorRow", "CapacityRow", "load_behavior_trace",
    "load_capacity_trace", "save_behavior_trace", "save_capacity_trace",
    "synthesize_behavior_trace", "synthesize_capacity_trace",
]
