"""FedScale-style client traces: load, save, and deterministic synthesis.

Two trace families drive the network/availability simulation
(``core/network.py``, DESIGN.md §9), mirroring the FedScale benchmark's
device traces (arXiv:2105.11367):

``capacity``
    Per-client link capability: uplink/downlink bandwidth (kbps, the
    FedScale unit) and last-mile latency (ms).  One row per client.

``behavior``
    Per-client availability: a list of ``(start_s, end_s)`` *active*
    windows, optionally repeating with ``period_s`` (diurnal traces use a
    24 h period).  A client is reachable only inside an active window.

Rows are plain dataclasses; loaders accept JSON (a list of row dicts) and
CSV (a header row naming the fields), so real FedScale dumps can be
converted with a one-line script.  The synthesizers generate rows
deterministically from a seed — same seed, same trace, same simulated
schedule — which is what the seeded-determinism tests pin down.
"""
from __future__ import annotations

import csv
import json
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class CapacityRow:
    """One client's link capability (FedScale device_capacity units)."""
    client_id: int
    uplink_kbps: float
    downlink_kbps: float
    latency_ms: float


@dataclass(frozen=True)
class BehaviorRow:
    """One client's availability: active windows within one period (or on
    an absolute axis when ``period_s`` is None)."""
    client_id: int
    active: Tuple[Tuple[float, float], ...]
    period_s: Optional[float] = None


# ---------------------------------------------------------------------------
# deterministic synthesis
# ---------------------------------------------------------------------------

def synthesize_capacity_trace(
        n_clients: int, seed: int = 0, dist: str = "lognormal",
        median_uplink_kbps: float = 12_000.0, sigma: float = 1.0,
        down_up_ratio: float = 5.0,
        latency_ms_range: Tuple[float, float] = (20.0, 120.0)
) -> List[CapacityRow]:
    """Sample per-client link rows from a seeded distribution.

    ``lognormal`` matches the measured FedScale/MobiPerf bandwidth shape
    (median ``median_uplink_kbps``, log-σ ``sigma``); ``uniform`` draws
    uplinks from ``[0.5, 1.5] × median`` (the benchmark's control cell).
    Downlink is ``down_up_ratio ×`` uplink (asymmetric consumer links);
    latency is uniform over ``latency_ms_range``.
    """
    rng = np.random.default_rng(seed)
    if dist == "lognormal":
        up = median_uplink_kbps * np.exp(
            sigma * rng.standard_normal(n_clients))
    elif dist == "uniform":
        up = rng.uniform(0.5 * median_uplink_kbps,
                         1.5 * median_uplink_kbps, size=n_clients)
    else:
        raise ValueError(f"unknown capacity dist {dist!r}")
    lat = rng.uniform(*latency_ms_range, size=n_clients)
    return [CapacityRow(client_id=c,
                        uplink_kbps=float(up[c]),
                        downlink_kbps=float(up[c] * down_up_ratio),
                        latency_ms=float(lat[c]))
            for c in range(n_clients)]


def synthesize_behavior_trace(
        n_clients: int, seed: int = 0, period_s: float = 86_400.0,
        duty_mean: float = 0.6, duty_jitter: float = 0.15
) -> List[BehaviorRow]:
    """Diurnal availability: each client is active for one contiguous
    window of ``duty × period`` seconds per period, phase-shifted uniformly
    (a window crossing the period boundary splits into two).  ``duty`` is
    clipped to [0.05, 0.95] so no client is always-on or always-off."""
    rng = np.random.default_rng(seed)
    rows: List[BehaviorRow] = []
    for c in range(n_clients):
        duty = float(np.clip(duty_mean + duty_jitter * rng.standard_normal(),
                             0.05, 0.95))
        start = float(rng.uniform(0.0, period_s))
        end = start + duty * period_s
        if end <= period_s:
            active: Tuple[Tuple[float, float], ...] = ((start, end),)
        else:
            active = ((0.0, end - period_s), (start, period_s))
        rows.append(BehaviorRow(client_id=c, active=active,
                                period_s=period_s))
    return rows


# ---------------------------------------------------------------------------
# load / save
# ---------------------------------------------------------------------------

_CAP_FIELDS = ("client_id", "uplink_kbps", "downlink_kbps", "latency_ms")


def _cap_from_dict(d: Dict) -> CapacityRow:
    return CapacityRow(client_id=int(d["client_id"]),
                       uplink_kbps=float(d["uplink_kbps"]),
                       downlink_kbps=float(d["downlink_kbps"]),
                       latency_ms=float(d["latency_ms"]))


def _beh_from_dict(d: Dict) -> BehaviorRow:
    period = d.get("period_s")
    return BehaviorRow(
        client_id=int(d["client_id"]),
        active=tuple((float(a), float(b)) for a, b in d["active"]),
        period_s=None if period is None else float(period))


def load_capacity_trace(path: str) -> List[CapacityRow]:
    """JSON (list of row dicts) or CSV (header = field names) by suffix."""
    if path.endswith(".csv"):
        with open(path, newline="") as f:
            return [_cap_from_dict(row) for row in csv.DictReader(f)]
    with open(path) as f:
        return [_cap_from_dict(row) for row in json.load(f)]


def load_behavior_trace(path: str) -> List[BehaviorRow]:
    """JSON only (windows don't flatten into CSV cells cleanly)."""
    with open(path) as f:
        return [_beh_from_dict(row) for row in json.load(f)]


def save_capacity_trace(path: str, rows: Sequence[CapacityRow]) -> None:
    if path.endswith(".csv"):
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=_CAP_FIELDS)
            w.writeheader()
            for r in rows:
                w.writerow(asdict(r))
        return
    with open(path, "w") as f:
        json.dump([asdict(r) for r in rows], f, indent=2)
        f.write("\n")


def save_behavior_trace(path: str, rows: Sequence[BehaviorRow]) -> None:
    with open(path, "w") as f:
        json.dump([asdict(r) for r in rows], f, indent=2)
        f.write("\n")
