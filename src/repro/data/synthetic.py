"""Synthetic federated datasets.

Offline-container stand-ins for FEMNIST / ImageNet / Reddit with the same
*system-level* characteristics (client counts, size heterogeneity, label
skew), generated deterministically:

  make_classification_clients — gaussian-blob classification (FEMNIST-like);
      each client draws from a Dir(α) or natural mixture of class blobs.
  make_lm_clients — token streams from per-client Markov chains (Reddit-like)
      for LM federated training.
  make_classification_population — the streamed twin of
      make_classification_clients: an O(M)-words registry (sizes come from
      the vectorized partition sampler) plus a per-client factory with
      per-client derived rng streams, wrapped in a LazyPopulation — million-
      client populations at O(cohort) resident data (DESIGN.md §11).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.algorithms import ClientData
from repro.core.population import LazyPopulation
from repro.data.partition import dirichlet_label_partition, partition_sizes


def _blob_means(n_classes: int, dim: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n_classes, dim)) * 2.0


def make_classification_clients(
        n_clients: int, dim: int = 32, n_classes: int = 10,
        partition: str = "natural", partition_arg: float = 0.1,
        mean_samples: int = 64, batch_size: int = 20, seed: int = 0
) -> Dict[int, ClientData]:
    """Returns client_id -> ClientData of (x, y) numpy batches."""
    rng = np.random.default_rng(seed)
    means = _blob_means(n_classes, dim, seed)
    sizes = partition_sizes(partition, n_clients, partition_arg,
                            mean_samples, seed)
    out: Dict[int, ClientData] = {}
    for c in range(n_clients):
        n = int(sizes[c])
        if partition == "dirichlet":
            mix = rng.dirichlet(np.full(n_classes, partition_arg))
        else:
            mix = rng.dirichlet(np.full(n_classes, 1.0))
        ys = rng.choice(n_classes, size=n, p=mix)
        xs = means[ys] + rng.normal(size=(n, dim)).astype(np.float32)
        batches = []
        for i in range(0, n, batch_size):
            xb = xs[i:i + batch_size].astype(np.float32)
            yb = ys[i:i + batch_size].astype(np.int32)
            if len(xb) < batch_size:   # pad to fixed shape (jit-friendly)
                pad = batch_size - len(xb)
                xb = np.concatenate([xb, xb[:pad] if len(xb) >= pad
                                     else np.repeat(xb, pad, 0)[:pad]])
                yb = np.concatenate([yb, yb[:pad] if len(yb) >= pad
                                     else np.repeat(yb, pad, 0)[:pad]])
            batches.append({"x": xb, "y": yb})
        out[c] = ClientData(batches=batches, n_samples=n)
    return out


def _build_classification_client(n: int, mix: np.ndarray, means: np.ndarray,
                                 batch_size: int, rng: np.random.Generator
                                 ) -> ClientData:
    """One client's gaussian-blob batches (shared by the eager generator's
    twin factory — padding/batching identical to
    ``make_classification_clients``)."""
    n_classes, dim = means.shape
    ys = rng.choice(n_classes, size=n, p=mix)
    xs = means[ys] + rng.normal(size=(n, dim)).astype(np.float32)
    batches = []
    for i in range(0, n, batch_size):
        xb = xs[i:i + batch_size].astype(np.float32)
        yb = ys[i:i + batch_size].astype(np.int32)
        if len(xb) < batch_size:   # pad to fixed shape (jit-friendly)
            pad = batch_size - len(xb)
            xb = np.concatenate([xb, xb[:pad] if len(xb) >= pad
                                 else np.repeat(xb, pad, 0)[:pad]])
            yb = np.concatenate([yb, yb[:pad] if len(yb) >= pad
                                 else np.repeat(yb, pad, 0)[:pad]])
        batches.append({"x": xb, "y": yb})
    return ClientData(batches=batches, n_samples=n)


def make_classification_population(
        n_clients: int, dim: int = 32, n_classes: int = 10,
        partition: str = "natural", partition_arg: float = 0.1,
        mean_samples: int = 64, batch_size: int = 20, seed: int = 0,
        fetch_cache_bytes: int = 256 << 20) -> LazyPopulation:
    """Streamed classification population: only the registry (per-client
    sample counts — one vectorized partition draw) is materialised up
    front; each client's batches synthesize on demand from a rng stream
    derived from ``(seed, client_id)``, so any access order (or an eager
    ``materialize()``) yields identical data.  Dataset memory is bounded by
    ``fetch_cache_bytes``, independent of ``n_clients``."""
    means = _blob_means(n_classes, dim, seed)
    sizes = partition_sizes(partition, n_clients, partition_arg,
                            mean_samples, seed)
    alpha = partition_arg if partition == "dirichlet" else 1.0

    def factory(c: int) -> ClientData:
        rng = np.random.default_rng((seed, 0x5EED, c))
        mix = rng.dirichlet(np.full(n_classes, alpha))
        return _build_classification_client(int(sizes[c]), mix, means,
                                            batch_size, rng)

    return LazyPopulation(sizes, factory,
                          fetch_cache_bytes=fetch_cache_bytes,
                          signature=("blobs", dim, n_classes, batch_size),
                          meta={"seed": seed, "partition": partition})


def make_lm_clients(
        n_clients: int, vocab: int = 256, seq_len: int = 64,
        partition: str = "natural", partition_arg: float = 5.0,
        mean_samples: int = 8, batch_size: int = 4, seed: int = 0
) -> Dict[int, ClientData]:
    """Per-client token streams (a sample = one sequence)."""
    rng = np.random.default_rng(seed)
    sizes = partition_sizes(partition, n_clients, partition_arg,
                            mean_samples, seed)
    out: Dict[int, ClientData] = {}
    for c in range(n_clients):
        n = int(sizes[c])
        # cheap per-client distribution: biased unigram sampling
        bias = rng.dirichlet(np.full(vocab, 0.5))
        toks = rng.choice(vocab, size=(n, seq_len + 1), p=bias)
        batches = []
        for i in range(0, n, batch_size):
            tb = toks[i:i + batch_size]
            if len(tb) < batch_size:
                tb = np.concatenate(
                    [tb, np.repeat(tb, batch_size, 0)[:batch_size - len(tb)]])
            batches.append({"inputs": tb[:, :-1].astype(np.int32),
                            "labels": tb[:, 1:].astype(np.int32)})
        out[c] = ClientData(batches=batches, n_samples=n)
    return out
