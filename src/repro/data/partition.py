"""Federated dataset partitioners (paper §5.1 / Appendix Table 4).

  natural        — LEAF-style per-client sizes (lognormal, like FEMNIST's
                   writer-based split: many small clients, a long tail)
  dirichlet(α)   — label-distribution skew (Hsu et al.): client class mix
                   drawn from Dir(α); sizes roughly balanced
  quantity_skew(σ) — sizes drawn lognormal(σ): pure quantity heterogeneity,
                   the axis the paper notes is what stresses scheduling

Only quantity skew affects system performance (paper footnote 1); dirichlet
matters for the algorithm-convergence experiments.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def natural_sizes(n_clients: int, mean_samples: int = 200,
                  seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    sizes = rng.lognormal(mean=np.log(mean_samples), sigma=0.8,
                          size=n_clients)
    return np.maximum(sizes.astype(int), 4)


def quantity_skew_sizes(n_clients: int, sigma: float = 5.0,
                        mean_samples: int = 200, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # the paper's "Quantity Skew(5.0)": heavier tail than natural
    sigma = np.log(max(sigma, 1.2))
    sizes = rng.lognormal(mean=np.log(mean_samples), sigma=sigma,
                          size=n_clients)
    return np.maximum(sizes.astype(int), 4)


def dirichlet_label_partition(labels: np.ndarray, n_clients: int,
                              alpha: float = 0.1, seed: int = 0
                              ) -> List[np.ndarray]:
    """Partition example indices by Dir(α)-skewed label distribution."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    client_indices: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(by_class):
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            client_indices[client].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_indices]


def partition_sizes(method: str, n_clients: int, arg: float = 0.1,
                    mean_samples: int = 200, seed: int = 0) -> np.ndarray:
    if method == "natural":
        return natural_sizes(n_clients, mean_samples, seed)
    if method == "quantity_skew":
        return quantity_skew_sizes(n_clients, arg, mean_samples, seed)
    if method == "dirichlet":
        # dirichlet skews labels, sizes stay near-uniform
        rng = np.random.default_rng(seed)
        sizes = rng.poisson(mean_samples, size=n_clients)
        return np.maximum(sizes, 4)
    raise ValueError(method)
