"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` computes the same function as the corresponding kernel with
plain jax.numpy, fp32 accumulation, no tiling.  Kernel tests sweep shapes and
dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jnp.ndarray:
    """q, k, v: (B, S, H, hd) (MHA layout; GQA callers pre-repeat kv)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = (1.0 / jnp.sqrt(jnp.float32(hd))) if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def agg_weighted_sum_ref(acc, deltas, weights) -> jnp.ndarray:
    """acc: (n,) fp32; deltas: (C, n) any float dtype; weights: (C,) fp32.
    Returns acc + Σ_c w_c · deltas[c] in fp32 — the hierarchical-aggregation
    fold (LocalAggregate inner loop)."""
    return acc + jnp.einsum("c,cn->n", weights.astype(jnp.float32),
                            deltas.astype(jnp.float32))


def ssm_scan_ref(q, k, v, log_a, h0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential scalar-decay linear recurrence (SSD/mLSTM core).

    q, k: (BH, S, N); v: (BH, S, P); log_a: (BH, S); h0: (BH, N, P).
    Returns (y: (BH, S, P), h_final)."""

    def body(h, t):
        a = jnp.exp(log_a[:, t].astype(jnp.float32))
        h = a[:, None, None] * h + \
            k[:, t, :, None].astype(jnp.float32) * v[:, t, None, :].astype(jnp.float32)
        y = jnp.einsum("bn,bnp->bp", q[:, t].astype(jnp.float32), h)
        return h, y

    h, ys = jax.lax.scan(body, h0.astype(jnp.float32), jnp.arange(q.shape[1]))
    return ys.transpose(1, 0, 2).astype(v.dtype), h


def rmsnorm_ref(x, g, eps: float = 1e-5) -> jnp.ndarray:
    """x: (T, d); g: (d,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)
