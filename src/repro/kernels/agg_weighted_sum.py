"""Hierarchical-aggregation fold kernel: ``acc += Σ_c w_c · delta_c``.

This is Parrot's memory-bound hot loop (LocalAggregate folds every simulated
client's multi-hundred-MB delta into the fp32 partial).  Arithmetic intensity
is ~0.5 FLOP/byte, so the kernel's job is purely to stream HBM→VMEM at line
rate with the multiply-add fused on the VPU — one pass over the deltas, fp32
accumulation regardless of delta dtype (bf16 deltas halve the bytes moved,
which is the §Perf lever for the aggregation benchmark).

Tiling: 1-D grid over n/BLK element blocks; the (C, BLK) delta tile and the
(BLK,) accumulator tile live in VMEM; weights ride in SMEM-like fashion as a
small replicated block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, acc_ref, delta_ref, o_ref):
    acc = acc_ref[...].astype(jnp.float32)            # (blk,)
    d = delta_ref[...].astype(jnp.float32)            # (C, blk)
    w = w_ref[...].astype(jnp.float32)                # (C,)
    o_ref[...] = acc + jax.lax.dot_general(
        w, d, (((0,), (0,)), ((), ())))               # w @ d -> (blk,)


def agg_weighted_sum(acc, deltas, weights, *, blk: int = 65536,
                     interpret: bool = True):
    """acc: (n,) fp32; deltas: (C, n); weights: (C,) -> (n,) fp32."""
    (n,) = acc.shape
    C = deltas.shape[0]
    blk = min(blk, n)
    pad = (-n) % blk
    if pad:
        acc = jnp.pad(acc, (0, pad))
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    npad = n + pad

    out = pl.pallas_call(
        _agg_kernel,
        grid=(npad // blk,),
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((C, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(weights, acc, deltas)
    return out[:n]
