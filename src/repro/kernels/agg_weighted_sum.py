"""Hierarchical-aggregation fold kernel: ``acc += Σ_c w_c · delta_c``.

This is Parrot's memory-bound hot loop (LocalAggregate folds every simulated
client's multi-hundred-MB delta into the fp32 partial).  Arithmetic intensity
is ~0.5 FLOP/byte, so the kernel's job is purely to stream HBM→VMEM at line
rate with the multiply-add fused on the VPU — one pass over the deltas, fp32
accumulation regardless of delta dtype (bf16 deltas halve the bytes moved,
which is the §Perf lever for the aggregation benchmark).

The C axis is the multi-client micro-batch: ``LocalAggregator`` flattens each
client's whole reducible payload into ONE contiguous (n,) buffer (see
``core.flat.FlatLayout``), stages B of them, and issues a single C=B call —
amortising dispatch overhead over B clients x all leaves instead of paying it
per leaf per client.  B is static via the (C, n) shape, so a fixed micro-batch
compiles exactly one kernel specialisation per layout.

Tiling: 1-D grid over n/BLK element blocks; the (C, BLK) delta tile and the
(BLK,) accumulator tile live in VMEM; weights ride in SMEM-like fashion as a
small replicated block.  When n is block-aligned the input is neither padded
nor sliced, and on the compiled (non-interpret) path the accumulator aliases
the output (``input_output_aliases``) so the fold updates it in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, acc_ref, delta_ref, o_ref):
    acc = acc_ref[...].astype(jnp.float32)            # (blk,)
    d = delta_ref[...].astype(jnp.float32)            # (C, blk)
    w = w_ref[...].astype(jnp.float32)                # (C,)
    o_ref[...] = acc + jax.lax.dot_general(
        w, d, (((0,), (0,)), ((), ())))               # w @ d -> (blk,)


def _auto_blk(n: int, C: int, delta_itemsize: int, interpret: bool) -> int:
    """Pick the element-block size.  Interpret mode (CPU validation) has no
    VMEM: one grid step over the whole buffer minimises the per-step
    interpreter overhead.  Compiled TPU fits the (C, blk) delta tile, its
    fp32 compute copy, and the acc/out tiles in a ~8MB VMEM budget, rounded
    down to the 128-lane tile."""
    if interpret:
        return n
    budget = 8 * 1024 * 1024
    per_elem = C * (delta_itemsize + 4) + 8          # deltas + f32 copy + acc/out
    blk = max(512, budget // per_elem)
    return max(128, (blk // 128) * 128)


def agg_weighted_sum(acc, deltas, weights, *, blk: int = 0,
                     interpret: bool = True):
    """acc: (n,) fp32; deltas: (C, n); weights: (C,) -> (n,) fp32.

    ``blk=0`` auto-sizes the block (see ``_auto_blk``); pass an explicit
    ``blk`` to pin the tiling (tests sweep it)."""
    (n,) = acc.shape
    C = deltas.shape[0]
    if not blk:
        blk = _auto_blk(n, C, deltas.dtype.itemsize, interpret)
    blk = min(blk, n)
    pad = (-n) % blk
    if pad:   # non-aligned n: pad in, slice out
        acc_in = jnp.pad(acc, (0, pad))
        deltas = jnp.pad(deltas, ((0, 0), (0, pad)))
    else:     # block-aligned n: no pad, no slice, aliasable accumulator
        acc_in = acc
    npad = n + pad
    alias = {} if (pad or interpret) else {1: 0}   # in-place acc on TPU

    out = pl.pallas_call(
        _agg_kernel,
        grid=(npad // blk,),
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((C, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        input_output_aliases=alias,
        interpret=interpret,
    )(weights, acc_in, deltas)
    return out[:n] if pad else out
