"""SSD chunked selective-scan Pallas kernel (Mamba-2 style; DESIGN.md §2).

Grid = (BH, S/L) with the chunk dimension innermost; TPU sequential-grid
semantics let the inter-chunk state h (N, P) persist in VMEM scratch, so the
recurrence crosses chunk boundaries without HBM round-trips.  Within a chunk
everything is (L × L) masked matmuls — MXU work, which is the whole point of
adapting the GPU selective-scan to TPU this way.

Per-step VMEM: q,k (L,N) + v (L,P) + decay/score (L,L) + h (N,P) — with
L=128..256, N=16..64, P≤512 this stays in the low MBs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, hout_ref, h_scr, *,
                L: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    q = q_ref[0].astype(jnp.float32)          # (L, N)
    k = k_ref[0].astype(jnp.float32)          # (L, N)
    v = v_ref[0].astype(jnp.float32)          # (L, P)
    la = la_ref[0].astype(jnp.float32)        # (L,)

    cum = jnp.cumsum(la)                      # inclusive log-decay prefix
    total = cum[-1]
    # intra-chunk: M[t,s] = (q_t·k_s)·exp(cum_t - cum_s) for s <= t
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (L, L)
    decay = cum[:, None] - cum[None, :]
    tmask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gate = jnp.where(tmask, jnp.exp(decay), 0.0)
    y_intra = jax.lax.dot_general(scores * gate, v, (((1,), (0,)), ((), ())))
    # inter-chunk: y_t += exp(cum_t) * q_t @ h
    qdec = q * jnp.exp(cum)[:, None]
    y_inter = jax.lax.dot_general(qdec, h_scr[...], (((1,), (0,)), ((), ())))
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update: h = exp(total)·h + Σ_s exp(total - cum_s) k_s v_sᵀ
    kdec = k * jnp.exp(total - cum)[:, None]
    h_scr[...] = jnp.exp(total) * h_scr[...] + \
        jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())))

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


def ssm_scan(q, k, v, log_a, *, chunk: int = 128, interpret: bool = True):
    """q, k: (BH, S, N); v: (BH, S, P); log_a: (BH, S) (log decay ≤ 0).

    Returns (y: (BH, S, P), h_final: (BH, N, P) fp32).  h0 = 0 (prefill
    convention; decode carries state outside the kernel)."""
    BH, S, N = q.shape
    P = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    n_chunks = S // L

    kernel = functools.partial(_ssm_kernel, L=L, n_chunks=n_chunks)
    y, h = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), v.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_a)
    return y, h
