"""Fused error-feedback top-k sparsification kernel (DESIGN.md §7).

One dispatch performs the whole error-feedback cycle for a 1-D segment:

    residual-add -> |.| top-k select -> gather values -> scatter-zero residual

Selection semantics (shared by the Pallas kernel and the jnp reference, and
the documented tie rule for the whole compression stack): the k entries with
the largest ``|x + residual|`` win; on exact magnitude ties the LOWER index
wins (``jax.lax.top_k``'s stability guarantee).  Emitted indices are sorted
ascending so the wire format is canonical regardless of backend.

On TPU the Pallas kernel keeps the residual update on-chip; elsewhere the
pure-``lax.top_k`` reference is the fast path (XLA fuses it fine on CPU/GPU)
and the kernel is still exercised under ``interpret=True`` by the tests,
following the pattern in ``kernels/ops.py``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _topk_core(f: Array, k: int) -> Tuple[Array, Array, Array]:
    """Select/gather/scatter on an already residual-added f32 vector."""
    _, top = jax.lax.top_k(jnp.abs(f), k)
    idx = jnp.sort(top).astype(jnp.int32)
    vals = jnp.take(f, idx)
    # idx is unique by construction (top_k indices): the hint lets XLA skip
    # the duplicate-index combine path in the scatter
    new_res = f.at[idx].set(0.0, unique_indices=True)
    return idx, vals, new_res


def topk_with_residual_reference(x: Array, res: Array, k: int):
    """Pure-jnp oracle: returns ``(idx, vals, new_residual)``."""
    f = (jnp.asarray(x, jnp.float32) + jnp.asarray(res, jnp.float32))
    return _topk_core(f, k)


def _topk_kernel(x_ref, r_ref, idx_ref, val_ref, res_ref, *, k: int):
    f = (x_ref[0, :] + r_ref[0, :]).astype(jnp.float32)
    idx, vals, new_res = _topk_core(f, k)
    idx_ref[0, :] = idx
    val_ref[0, :] = vals
    res_ref[0, :] = new_res


def _pad128(n: int) -> int:
    return max(128, -(-n // 128) * 128)


def topk_with_residual_pallas(x: Array, res: Array, k: int, *,
                              interpret: bool = True):
    """Fused kernel over a single (1, n) block.

    Inputs are zero-padded to a 128-lane multiple for the TPU layout; the
    pad is harmless for selection because a padded zero at index >= n can
    only displace a real entry on an exact |0| tie, which it then loses by
    the lower-index rule (k <= n always).
    """
    n = int(x.shape[0])
    n_pad = n if interpret else _pad128(n)
    xp = jnp.asarray(x, jnp.float32)
    rp = jnp.asarray(res, jnp.float32)
    if n_pad != n:
        xp = jnp.pad(xp, (0, n_pad - n))
        rp = jnp.pad(rp, (0, n_pad - n))
    idx, vals, new_res = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        out_shape=(
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        ),
        interpret=interpret,
    )(xp.reshape(1, n_pad), rp.reshape(1, n_pad))
    return idx[0], vals[0], new_res[0, :n]


def topk_with_residual(x: Array, res: Array, k: int):
    """Backend dispatch (the building block the group codec jits call):
    compiled Pallas on TPU, the lax.top_k reference everywhere else."""
    if jax.default_backend() == "tpu":
        return topk_with_residual_pallas(x, res, k, interpret=False)
    return topk_with_residual_reference(x, res, k)
