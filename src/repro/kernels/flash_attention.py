"""Flash attention Pallas TPU kernel (online softmax, causal + sliding
window).

Tiling: grid = (B*H, Sq/BLK_Q, Skv/BLK_K) with the KV dimension innermost.
TPU grids execute sequentially per core, so the running max / normaliser /
output accumulator live in VMEM scratch and persist across the KV iterations
of a fixed (bh, q-block) — the same online-softmax recurrence as the pure-jnp
``chunked_attention`` reference, tiled for VMEM.

Block shapes default to (128, 128): the MXU-native tile (q·kᵀ is a
(BLK_Q, hd) × (hd, BLK_K) matmul with hd ∈ {64, 96, 128, 192} — second-minor
alignment handled by the compiler).  VMEM footprint per step ≈
BLK_Q·hd (q) + 2·BLK_K·hd (k, v) + BLK_Q·BLK_K (scores) + scratch
≈ 4 tiles of fp32 → well under the ~16 MB VMEM budget; BLK_Q/BLK_K are
exposed for the §Perf sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  blk_q: int, blk_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (blk_q, hd)
    k = k_ref[0].astype(jnp.float32)                  # (blk_k, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (blk_q, blk_k)

    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (blk_q, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                            # (blk_q, blk_k)
    corr = jnp.exp(m_prev - m_new)                    # (blk_q, 1)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                  # (blk_k, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale: float | None = None, blk_q: int = 128,
                         blk_k: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, hd) — flattened batch*heads layout.

    ``interpret=True`` runs the kernel body in Python on CPU (the validation
    mode for this container); on real TPUs pass ``interpret=False``.
    """
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    assert Sq % blk_q == 0 and Skv % blk_k == 0, (Sq, blk_q, Skv, blk_k)
    n_kv = Skv // blk_k
    scale = float(1.0 / (hd ** 0.5)) if scale is None else scale

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // blk_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
