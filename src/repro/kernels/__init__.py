"""Pallas TPU kernels for the perf-critical compute hot-spots.

  flash_attention   — online-softmax attention (train/prefill hot-spot)
  agg_weighted_sum  — Parrot hierarchical-aggregation fold (memory-bound)
  ssm_scan          — SSD chunked selective scan (hymba / xlstm mixers)
  rmsnorm           — fused normalisation

``ops`` holds the jit'd wrappers; ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
