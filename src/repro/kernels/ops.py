"""Jit'd public wrappers around the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (Python
evaluation of the kernel body — the validation mode); on TPU backends the
wrappers select the compiled path automatically.  The model code calls these
through ``cfg.attention_impl="pallas"`` etc.; the dry-run lowers the pure-jnp
references instead so the HLO stays analysable (DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import agg_weighted_sum as _agg
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssm_scan as _ssm
from repro.kernels import topk_compress as _tkc


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128):
    """q, k, v: (B, S, H, hd) MHA layout (GQA callers pre-repeat kv)."""
    B, S, H, hd = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    o = _fa.flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                 blk_q=blk_q, blk_k=blk_k,
                                 interpret=_use_interpret())
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


_agg_dispatch_count = 0


def agg_dispatch_count() -> int:
    """Kernel dispatches issued through ``agg_weighted_sum`` so far (one per
    call site, not per grid block) — the bench_aggregation metric."""
    return _agg_dispatch_count


def reset_agg_dispatch_count() -> None:
    global _agg_dispatch_count
    _agg_dispatch_count = 0


@jax.jit
def _agg_ws(acc, deltas, weights):
    return _agg.agg_weighted_sum(acc, deltas, weights,
                                 interpret=_use_interpret())


@functools.partial(jax.jit, donate_argnums=(0,))
def _agg_ws_donated(acc, deltas, weights):
    return _agg.agg_weighted_sum(acc, deltas, weights,
                                 interpret=_use_interpret())


def agg_weighted_sum(acc, deltas, weights, *, donate: bool = False):
    """acc: (n,) fp32; deltas: (C, n); weights: (C,) -> (n,) fp32.

    One dispatch folds C clients — both for restacked micro-batches and for
    the already-stacked (B, n) buffers the vmapped client engine emits
    (``LocalAggregator.fold_block``).  The micro-batch B is static through
    the (C, n) shape: a ``LocalAggregator`` flushing at a fixed B compiles
    exactly one kernel per layout.  ``donate=True`` donates the accumulator
    (TPU in-place update, no copy); only pass it when no other reference to
    ``acc`` is live."""
    global _agg_dispatch_count
    _agg_dispatch_count += 1
    fn = _agg_ws_donated if (donate and jax.default_backend() == "tpu") \
        else _agg_ws
    return fn(acc, deltas, weights)


@jax.jit
def _agg_ws_staged(acc, staged, weights):
    return _agg.agg_weighted_sum(acc, jnp.stack(staged), weights,
                                 interpret=_use_interpret())


@functools.partial(jax.jit, donate_argnums=(0,))
def _agg_ws_staged_donated(acc, staged, weights):
    return _agg.agg_weighted_sum(acc, jnp.stack(staged), weights,
                                 interpret=_use_interpret())


def agg_fold_batch(acc, staged, weights, *, donate: bool = False):
    """Fused micro-batch flush: stack B staged (n,) client buffers and fold
    them into the fp32 accumulator with ONE kernel dispatch.  ``staged`` is
    a tuple of B same-shape buffers (B static through the tuple length), so
    XLA fuses the stack into the kernel's input and a fixed micro-batch
    compiles exactly one executable per layout."""
    global _agg_dispatch_count
    _agg_dispatch_count += 1
    fn = _agg_ws_staged_donated if (donate and jax.default_backend() == "tpu") \
        else _agg_ws_staged
    return fn(acc, tuple(staged), weights)


def agg_fold(acc, delta, weight: float):
    """Fold a single client delta (any pytree leaf shape) into the fp32
    accumulator.  Legacy per-leaf C=1 path: one dispatch per leaf per
    client — superseded by the flat-buffer ``LocalAggregator`` micro-batch
    fold, kept as the bench_aggregation baseline and for ad-hoc folds."""
    flat_acc = acc.reshape(-1).astype(jnp.float32)
    flat_d = delta.reshape(1, -1)
    w = jnp.asarray([weight], jnp.float32)
    return agg_weighted_sum(flat_acc, flat_d, w).reshape(acc.shape)


@functools.partial(jax.jit, static_argnames=("k",))
def fused_topk(x, res, *, k: int):
    """Fused error-feedback top-k for one 1-D fp32 segment: residual-add,
    |.| top-k (ties -> lower index), gather, scatter-zero residual — ONE
    dispatch.  Returns ``(idx, vals, new_residual)``; ``idx`` ascending.
    The group codecs in ``core/compression.py`` call the underlying
    ``topk_compress`` building block inside their own per-group jit; this
    wrapper is the standalone entry point (benchmarks, ad-hoc use)."""
    return _tkc.topk_with_residual(x, res, k)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan(q, k, v, log_a, *, chunk: int = 128):
    """q, k: (BH, S, N); v: (BH, S, P); log_a: (BH, S)."""
    return _ssm.ssm_scan(q, k, v, log_a, chunk=chunk,
                         interpret=_use_interpret())


@jax.jit
def rmsnorm(x, g, eps: float = 1e-5):
    """x: (..., d) -> fused rmsnorm over the last axis."""
    shape = x.shape
    out = _rms.rmsnorm(x.reshape(-1, shape[-1]), g, eps=eps,
                       interpret=_use_interpret())
    return out.reshape(shape)
