"""Jit'd public wrappers around the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (Python
evaluation of the kernel body — the validation mode); on TPU backends the
wrappers select the compiled path automatically.  The model code calls these
through ``cfg.attention_impl="pallas"`` etc.; the dry-run lowers the pure-jnp
references instead so the HLO stays analysable (DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import agg_weighted_sum as _agg
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssm_scan as _ssm


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128):
    """q, k, v: (B, S, H, hd) MHA layout (GQA callers pre-repeat kv)."""
    B, S, H, hd = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    o = _fa.flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                 blk_q=blk_q, blk_k=blk_k,
                                 interpret=_use_interpret())
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@jax.jit
def agg_weighted_sum(acc, deltas, weights):
    """acc: (n,) fp32; deltas: (C, n); weights: (C,)."""
    return _agg.agg_weighted_sum(acc, deltas, weights,
                                 interpret=_use_interpret())


def agg_fold(acc, delta, weight: float):
    """Fold a single client delta (any pytree leaf shape) into the fp32
    accumulator — the LocalAggregator fast path."""
    flat_acc = acc.reshape(-1).astype(jnp.float32)
    flat_d = delta.reshape(1, -1)
    w = jnp.asarray([weight], jnp.float32)
    return agg_weighted_sum(flat_acc, flat_d, w).reshape(acc.shape)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan(q, k, v, log_a, *, chunk: int = 128):
    """q, k: (BH, S, N); v: (BH, S, P); log_a: (BH, S)."""
    return _ssm.ssm_scan(q, k, v, log_a, chunk=chunk,
                         interpret=_use_interpret())


@jax.jit
def rmsnorm(x, g, eps: float = 1e-5):
    """x: (..., d) -> fused rmsnorm over the last axis."""
    shape = x.shape
    out = _rms.rmsnorm(x.reshape(-1, shape[-1]), g, eps=eps,
                       interpret=_use_interpret())
    return out.reshape(shape)
