"""Fused RMSNorm Pallas kernel.

Unfused, RMSNorm reads x twice (variance pass + normalise pass) and writes an
intermediate; fused it is a single HBM read + write per element.  Tiling:
grid over row blocks; each step loads a (BLK_ROWS, d) tile, reduces the
squared mean on the VPU, and writes the normalised tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                # (blk, d)
    var = jnp.mean(x * x, axis=1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, g, *, eps: float = 1e-5, blk_rows: int = 256,
            interpret: bool = True):
    """x: (T, d); g: (d,)."""
    T, d = x.shape
    blk = min(blk_rows, T)
    pad = (-T) % blk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Tp // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        interpret=interpret,
    )(x, g)
    return out[:T]
