"""Mixture-of-Experts FFN with top-k routing and fixed expert capacity.

Two dispatch implementations, selected by ``MoEConfig.dispatch_impl``:

- ``gshard_einsum``: the classic GShard one-hot dispatch/combine einsums over
  token groups.  SPMD-safe under GSPMD partitioning at 512 devices (only
  einsums + cumsums — no data-dependent gathers), so it is the baseline used
  for the dry-run.  Its FLOP overhead is O(group * E * capacity * d) per group
  which is visible in the roofline "useful FLOPs" ratio — the perf hillclimb
  replaces it for top-1 models.
- ``gather``: index-based dispatch (argsort by expert, fixed-capacity gather /
  scatter-add).  ~E*capacity/ (k*S) times cheaper in FLOPs; used after the
  §Perf iteration validated its collective behaviour.

Experts are SwiGLU.  An auxiliary load-balancing loss (Switch-style) is
returned alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    dtype = jnp.dtype(cfg.dtype)
    kr, k1, k2, k3 = jax.random.split(key, 4)
    import numpy as np
    scale = 1.0 / np.sqrt(d)
    return {
        "router": (jax.random.normal(kr, (d, E), jnp.float32) * 0.02).astype(dtype),
        "wi": (jax.random.normal(k1, (E, d, f), jnp.float32) * scale).astype(dtype),
        "wg": (jax.random.normal(k2, (E, d, f), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(k3, (E, f, d), jnp.float32) / np.sqrt(f)).astype(dtype),
    }


def _routing(params, xg, m):
    """xg: (G, S, d) grouped tokens -> gating info.

    Returns (probs (G,S,E) fp32, topk_prob (G,S,k), topk_idx (G,S,k), aux_loss).
    """
    # matmul in model dtype: upcasting xg here would promote the whole
    # residual cotangent to f32 (observed: 2x backward activation memory)
    logits = (xg @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,S,E)
    topk_prob, topk_idx = jax.lax.top_k(probs, m.top_k)          # (G,S,k)
    # normalise combine weights over the selected experts
    topk_prob = topk_prob / jnp.maximum(
        jnp.sum(topk_prob, axis=-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e (fraction routed to e * mean prob e)
    E = probs.shape[-1]
    sel = jax.nn.one_hot(topk_idx[..., 0], E, dtype=jnp.float32)  # top-1 counts
    frac = jnp.mean(sel, axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return probs, topk_prob, topk_idx, aux


def _expert_ffn(params, h):
    """h: (E, C, d) -> (E, C, d) via per-expert SwiGLU (grouped einsums).

    Weights pass through an explicit ZeRO gather point (constrain) so the
    contraction dims are replicated at use: forward all-gathers the weight
    shards once per layer; backward reduce-scatters the weight grads — no
    partial-sum all-reduce of the (E, C, d/f) activation buffers."""
    from repro.sharding.specs import constrain
    wi = constrain(params["wi"], "moe_weight")
    wg = constrain(params["wg"], "moe_weight")
    wo = constrain(params["wo"], "moe_weight_row")
    up = jnp.einsum("ecd,edf->ecf", h, wi)
    gate = jnp.einsum("ecd,edf->ecf", h, wg)
    act = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", act, wo)


def _moe_gshard(params, xg, m):
    """GShard einsum dispatch.  xg: (G, S, d)."""
    G, S, d = xg.shape
    E, k = m.n_experts, m.top_k
    C = max(1, int(m.capacity_factor * S * k / E))
    probs, topk_prob, topk_idx, aux = _routing(params, xg, m)

    # position of each (token, k) assignment within its expert's buffer
    onehot_e = jax.nn.one_hot(topk_idx, E, dtype=jnp.int32)      # (G,S,k,E)
    flat = onehot_e.reshape(G, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                           # (G,S*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, S, k)          # (G,S,k)
    # one_hot of an out-of-range index is all-zero, so capacity overflow
    # (pos >= C) drops the token with no extra masking.
    onehot_c = jax.nn.one_hot(pos, C, dtype=xg.dtype)            # (G,S,k,C)
    oe = onehot_e.astype(xg.dtype)
    # dispatch tensor (G,S,E,C): 1 where token s fills slot (e,c)
    disp = jnp.einsum("gske,gskc->gsec", oe, onehot_c)
    comb = jnp.einsum("gsk,gske,gskc->gsec",
                      topk_prob.astype(xg.dtype), oe, onehot_c)

    h = jnp.einsum("gsec,gsd->gecd", disp, xg)                   # (G,E,C,d)
    out_e = jax.vmap(lambda hh: _expert_ffn(params, hh))(h)      # (G,E,C,d)
    out = jnp.einsum("gsec,gecd->gsd", comb, out_e)
    return out, aux


def _moe_gather(params, xg, m):
    """Index-based dispatch: argsort tokens by expert, fixed-capacity buffers.

    FLOPs: only the expert matmuls (plus O(S k log) sort) — no O(S*E*C*d)
    dispatch einsum.  Uses gather/scatter-add which GSPMD lowers with the
    tokens replicated along the model axis (validated in the dry-run).
    """
    G, S, d = xg.shape
    E, k = m.n_experts, m.top_k
    C = max(1, int(m.capacity_factor * S * k / E))
    probs, topk_prob, topk_idx, aux = _routing(params, xg, m)

    def per_group(x, idx, w):
        # x: (S,d); idx,w: (S,k)
        fi = idx.reshape(-1)                                     # (S*k,)
        fw = w.reshape(-1)
        order = jnp.argsort(fi)                                  # stable
        fi_s, fw_s = fi[order], fw[order]
        tok_s = order // k                                       # source token
        # slot within expert = rank within its expert segment
        seg_start = jnp.searchsorted(fi_s, jnp.arange(E))        # (E,)
        slot = jnp.arange(S * k) - seg_start[fi_s]
        keep = slot < C
        buf_idx = jnp.where(keep, fi_s * C + slot, E * C)        # overflow row
        buf = jnp.zeros((E * C + 1, d), x.dtype).at[buf_idx].set(x[tok_s])
        out_e = _expert_ffn(params, buf[:E * C].reshape(E, C, d))
        flat_out = out_e.reshape(E * C, d)
        gathered = jnp.where(keep[:, None],
                             flat_out[jnp.where(keep, buf_idx, 0)], 0.0)
        y = jnp.zeros((S, d), x.dtype).at[tok_s].add(
            gathered * fw_s[:, None].astype(x.dtype))
        return y

    out = jax.vmap(per_group)(xg, topk_idx, topk_prob)
    return out, aux


def moe_ffn(params, x, cfg):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    gs = min(m.group_size, T)
    # group along batch-row boundaries where possible so the (B@dp, S@model)
    # sharding survives the reshape (see chunked_xent for the failure mode);
    # rows are split (S % gs == 0) or batched together (gs % S == 0)
    if S % gs == 0 or gs % S == 0:
        pad = 0
        xg = x.reshape(T // gs, gs, d)
    else:
        pad = (-T) % gs
        xf = x.reshape(T, d)
        if pad:  # pad to a whole number of groups (dropped after)
            xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)])
        xg = xf.reshape((T + pad) // gs, gs, d)
    from repro.sharding.specs import constrain
    xg = constrain(xg, "moe_group")
    if m.dispatch_impl == "gather":
        out, aux = _moe_gather(params, xg, m)
    else:
        out, aux = _moe_gshard(params, xg, m)
    if pad == 0:
        return out.reshape(B, S, d), aux
    out = out.reshape(T + pad, d)[:T]
    return out.reshape(B, S, d), aux
