"""Attention: GQA with dense / chunked-online-softmax (flash-style) impls.

The ``chunked`` implementation is the pure-jnp expression of the same
online-softmax algorithm as the Pallas flash kernel (``kernels/flash_attention``)
— it is both the memory-efficient path used when lowering the dry-run and the
oracle against which the kernel is validated.

KV caches are ring buffers: ``{"k": (B,Smax,KV,hd), "v": ..., "pos": (Smax,)}``
where ``pos[s]`` is the absolute position stored in slot ``s`` (-1 = empty).
For full-attention archs Smax == seq_len and the ring never wraps; for
sliding-window archs Smax == window and old entries are overwritten — this is
what makes ``long_500k`` decode O(window) instead of O(context).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.specs import constrain, tp_padded_heads

NEG_INF = -1e30


def attn_init(key, cfg) -> dict:
    """Projection weights keep an explicit head axis — (d, H, hd) — so the
    head dim is shardable over the "model" mesh axis even when H is not a
    multiple of it (GSPMD pad-shards), with no reshape to break propagation."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dtype = jnp.dtype(cfg.dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    import numpy as np
    scale = 1.0 / np.sqrt(d)

    def proj(k, n_heads):
        p = {"w": (jax.random.normal(k, (d, n_heads, hd), jnp.float32)
                   * scale).astype(dtype)}
        if cfg.qkv_bias:
            p["b"] = jnp.zeros((n_heads, hd), dtype)
        return p

    return {
        "wq": proj(kq, H),
        "wk": proj(kk, KV),
        "wv": proj(kv, KV),
        "wo": {"w": (jax.random.normal(ko, (H, hd, d), jnp.float32)
                     / np.sqrt(H * hd)).astype(dtype)},
    }


def _proj_heads(p, x):
    """x: (B,S,d) @ (d,Hn,hd) -> (B,S,Hn,hd)."""
    y = jnp.einsum("bsd,dhk->bshk", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def init_cache(cfg, batch: int, seq_len: int, dtype) -> dict:
    smax = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, smax, KV, hd), dtype),
        "v": jnp.zeros((batch, smax, KV, hd), dtype),
        "pos": jnp.full((smax,), -1, jnp.int32),
    }


def _split_heads(x, n):
    return x.reshape(x.shape[:-1] + (n, x.shape[-1] // n))


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference attention.  q: (B,Sq,H,hd); k,v: (B,Skv,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 512, q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention scanning over KV chunks (flash-style).

    Never materialises the (Sq, Skv) score matrix; peak transient is
    (B, H, Sq, chunk).  Matches ``dense_attention`` to fp32 accuracy.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    if Skv % chunk:
        chunk = Skv  # degenerate fallback for tiny shapes
    n_chunks = Skv // chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q.astype(jnp.float32) * scale
    qpos = (jnp.arange(Sq) + q_offset)[:, None]          # (Sq, 1)

    kc = k.reshape(B, n_chunks, chunk, H, hd)
    vc = v.reshape(B, n_chunks, chunk, H, hd)

    def body(carry, inp):
        m, l, acc = carry                                # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd)
        kb, vb, idx = inp                                # (B,chunk,H,hd)
        kpos = idx * chunk + jnp.arange(chunk)[None, :]  # (1, chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        # additive f32 mask of shape (Sq, chunk) only — a broadcast boolean
        # where() tempts XLA into hoisting a stacked (n_chunks,B,H,Sq,chunk)
        # predicate out of the scan (observed on the dry-run: 469 MB/device)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > (qpos - window)
        s = s + jnp.where(mask, 0.0, NEG_INF)[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])                # (B,H,Sq,chunk)
        corr = jnp.exp(m - m_new)                        # (B,H,Sq)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    idxs = jnp.arange(n_chunks)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), idxs))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def _cache_attend(q, cache, cfg, qpos):
    """Attend new-token queries over the ring-buffer cache (decode path)."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    groups = H // KV
    kk = _repeat_kv(cache["k"], groups)
    vv = _repeat_kv(cache["v"], groups)
    kpos = cache["pos"]                                  # (Smax,)
    valid = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
    if cfg.sliding_window:
        valid &= kpos[None, :] > (qpos[:, None] - cfg.sliding_window)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    s = jnp.where(valid[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", (p / l), vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(params, x, cfg, *, positions, cache=None, cache_index=None,
              impl: Optional[str] = None):
    """Full GQA attention layer.

    x: (B, S, d).  Three modes:
      - training (cache is None): causal self-attention over S.
      - prefill (cache given, S > 1): causal self-attention, cache filled.
      - decode (cache given, S == 1): attend over the ring-buffer cache.

    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    wq, wo = params["wq"], params["wo"]
    Hp = tp_padded_heads(H, KV) if cache is None else H
    if Hp != H:
        # zero-pad query heads to the TP multiple (exact: padded wo rows are
        # zero, so phantom heads contribute nothing)
        wq = {k_: jnp.pad(v_, [(0, 0)] * (v_.ndim - 2)
                          + [(0, Hp - H), (0, 0)])
              for k_, v_ in wq.items()}
        wo = {"w": jnp.pad(wo["w"], [(0, Hp - H), (0, 0), (0, 0)])}
        H = Hp
    from repro.sharding.specs import head_tp_active
    kv_kind = "kv_heads" if head_tp_active(H) else "heads"
    q = constrain(_proj_heads(wq, x), "heads")               # (B,S,H,hd)
    k = constrain(_proj_heads(params["wk"], x), kv_kind)
    v = constrain(_proj_heads(params["wv"], x), kv_kind)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    groups = H // KV

    new_cache = None
    if cache is not None and S == 1:
        smax = cache["k"].shape[1]
        slot = cache_index % smax
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.reshape(1).astype(jnp.int32), slot, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        qpos = positions.reshape(1)
        out = _cache_attend(q, new_cache, cfg, qpos)
    else:
        kk = _repeat_kv(k, groups)
        vv = _repeat_kv(v, groups)
        use = impl or cfg.attention_impl
        if use == "dense":
            out = dense_attention(q, kk, vv, causal=True,
                                  window=cfg.sliding_window)
        elif use == "pallas":
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, kk, vv, causal=True,
                                       window=cfg.sliding_window)
        else:  # chunked reference (used for dry-run lowering)
            out = chunked_attention(q, kk, vv, causal=True,
                                    window=cfg.sliding_window,
                                    chunk=min(cfg.attn_chunk, x.shape[1]))
        if cache is not None:  # prefill: write the (possibly windowed) tail
            smax = cache["k"].shape[1]
            ktail = k[:, -smax:].astype(cache["k"].dtype)
            vtail = v[:, -smax:].astype(cache["v"].dtype)
            tailpos = positions[-smax:].astype(jnp.int32)
            if smax == S:
                # full cache, prefill from position 0: slots are identity
                new_cache = {"k": ktail, "v": vtail, "pos": tailpos}
            else:
                # sliding window: store the tail at its ring slots
                slot = tailpos % smax
                ck = cache["k"].at[:, slot].set(ktail)
                cv = cache["v"].at[:, slot].set(vtail)
                cpos = cache["pos"].at[slot].set(tailpos)
                new_cache = {"k": ck, "v": cv, "pos": cpos}
    out = jnp.einsum("bshk,hkd->bsd", out, wo["w"])
    return out, new_cache
