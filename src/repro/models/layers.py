"""Basic neural-net layers (pure functional, pytree params).

All layers follow the same convention: ``init_*(key, ...) -> params dict`` and
a pure apply function.  Parameters are stored in the dtype given by the model
config; math runs in that dtype with fp32 accumulation where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Params = dict


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: float | None = None) -> Params:
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"w": w.astype(dtype)}


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["w"], ids, axis=0)


def rmsnorm_init(d: int, dtype) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd//2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd//2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, f, dtype),
        "wg": dense_init(k2, d, f, dtype),
        "wo": dense_init(k3, f, d, dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    return dense(p["wo"], h)
