"""Decoder stack: block composition over heterogeneous block kinds.

Layers are grouped into a repeating *unit* (e.g. ``("dense",)`` for
transformers, ``("mlstm", "slstm")`` for xLSTM) and the stack is evaluated as
``lax.scan`` over ``n_layers / len(unit)`` repetitions with stacked params —
this keeps HLO size and compile time flat in depth (MaxText-style) and is what
makes 64-layer dry-runs tractable.  ``cfg.remat`` wraps each unit in
``jax.checkpoint`` so only unit-boundary activations are saved.

Block kinds:
  dense   — RMSNorm → GQA attention → residual → RMSNorm → SwiGLU/MoE → residual
  hybrid  — parallel attention + mamba(SSD) heads fused by averaging (Hymba)
  mlstm   — RMSNorm → mLSTM mixer → residual (xLSTM, no FFN)
  slstm   — RMSNorm → sLSTM mixer → residual
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, ssm
from repro.sharding.specs import constrain


def unit_pattern(cfg) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        pat = tuple((cfg.xlstm.pattern if cfg.xlstm else ("mlstm", "slstm")))
        return pat
    if cfg.family == "hybrid":
        return ("hybrid",)
    return ("dense",)


def n_rep(cfg) -> int:
    pat = unit_pattern(cfg)
    assert cfg.n_layers % len(pat) == 0
    return cfg.n_layers // len(pat)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind: str) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"norm1": layers.rmsnorm_init(d, dtype)}
    if kind in ("dense", "hybrid"):
        p["attn"] = attention.attn_init(ks[0], cfg)
        if kind == "hybrid":
            p["mamba"] = ssm.mamba_init(ks[1], cfg)
        if cfg.d_ff > 0:
            p["norm2"] = layers.rmsnorm_init(d, dtype)
            if cfg.moe is not None and kind == "dense":
                p["ffn"] = moe.moe_init(ks[2], cfg)
            else:
                p["ffn"] = layers.swiglu_init(ks[2], d, cfg.d_ff, dtype)
    elif kind == "mlstm":
        p["mixer"] = ssm.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def block_cache(cfg, kind: str, batch: int, seq_len: int, dtype) -> dict:
    """Decode cache/state pytree for one block."""
    c = {}
    if kind in ("dense", "hybrid"):
        c["attn"] = attention.init_cache(cfg, batch, seq_len, dtype)
    if kind == "hybrid":
        c["mamba"] = ssm.mamba_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        c["mixer"] = ssm.mlstm_init_state(cfg, batch, dtype)
    if kind == "slstm":
        c["mixer"] = ssm.slstm_init_state(cfg, batch, dtype)
    return c


def block_apply(p, x, cfg, kind: str, *, positions, cache=None,
                cache_index=None, decode: bool = False):
    """Returns (x_out, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    if kind in ("dense", "hybrid"):
        h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
        attn_cache = cache.get("attn") if cache else None
        a_out, new_attn = attention.attention(
            p["attn"], h, cfg, positions=positions, cache=attn_cache,
            cache_index=cache_index)
        if kind == "hybrid":
            if decode:
                m_out, new_m = ssm.mamba_step(p["mamba"], h, cache["mamba"], cfg)
                new_cache["mamba"] = new_m
            else:
                m_out, (conv_st, h_st) = ssm.mamba_apply(p["mamba"], h, cfg)
                if cache is not None:
                    # prefill: seed the decode state from the scan tail
                    new_cache["mamba"] = {"conv": _conv_tail(p, h, cfg),
                                          "h": h_st}
            mixed = (a_out + m_out) * 0.5
        else:
            mixed = a_out
        if new_attn is not None:
            new_cache["attn"] = new_attn
        x = x + mixed
        if cfg.d_ff > 0:
            h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
            if cfg.moe is not None and kind == "dense":
                f_out, aux = moe.moe_ffn(p["ffn"], h2, cfg)
            else:
                f_out = layers.swiglu(p["ffn"], h2)
            x = x + f_out
    elif kind == "mlstm":
        h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
        if decode:
            m_out, st = ssm.mlstm_step(p["mixer"], h, cache["mixer"], cfg)
            new_cache["mixer"] = st
        else:
            m_out, h_final = ssm.mlstm_apply(p["mixer"], h, cfg)
            if cache is not None:
                new_cache["mixer"] = {"h": h_final}
        x = x + m_out
    elif kind == "slstm":
        h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
        if decode:
            m_out, st = ssm.slstm_step(p["mixer"], h, cache["mixer"], cfg)
            new_cache["mixer"] = st
        else:
            m_out, st = ssm.slstm_apply(p["mixer"], h, cfg)
            if cache is not None:
                new_cache["mixer"] = st
        x = x + m_out
    return x, new_cache, aux


def _conv_tail(p, h, cfg):
    """Streaming conv state after a prefill pass: last (K-1) pre-conv inputs.

    The mamba conv operates on the in_proj output, so recompute that tail."""
    u = layers.dense(p["mamba"]["in_proj"], h[:, -(cfg.ssm.d_conv - 1):, :])
    xs, _ = jnp.split(u, 2, axis=-1)
    return xs


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def stack_init(key, cfg) -> Tuple[dict, ...]:
    pat = unit_pattern(cfg)
    reps = n_rep(cfg)
    out = []
    for i, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(key, i), reps)
        out.append(jax.vmap(lambda k: block_init(k, cfg, kind))(keys))
    return tuple(out)


def stack_cache(cfg, batch: int, seq_len: int, dtype):
    pat = unit_pattern(cfg)
    reps = n_rep(cfg)
    out = []
    for kind in pat:
        c = block_cache(cfg, kind, batch, seq_len, dtype)
        out.append(jax.tree.map(
            lambda a: jnp.tile(a[None], (reps,) + (1,) * a.ndim), c))
    return tuple(out)


def stack_apply(params, x, cfg, *, positions, caches=None, cache_index=None,
                decode: bool = False):
    """params/caches: tuple over pattern positions of stacked pytrees.

    Returns (x, new_caches, aux_total).
    """
    pat = unit_pattern(cfg)
    reps = n_rep(cfg)
    has_cache = caches is not None

    def unit(x, unit_params, unit_caches):
        x = constrain(x, "residual")
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, kind in enumerate(pat):
            c = unit_caches[i] if has_cache else None
            x, nc, a = block_apply(unit_params[i], x, cfg, kind,
                                   positions=positions, cache=c,
                                   cache_index=cache_index, decode=decode)
            new_caches.append(nc)
            aux = aux + a
        return x, tuple(new_caches), aux

    if cfg.remat:
        unit = jax.checkpoint(unit)

    if not cfg.scan_layers:
        aux_tot = jnp.zeros((), jnp.float32)
        new_all = []
        for r in range(reps):
            up = jax.tree.map(lambda a: a[r], params)
            uc = jax.tree.map(lambda a: a[r], caches) if has_cache else None
            x, nc, a = unit(x, up, uc)
            new_all.append(nc)
            aux_tot = aux_tot + a
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_all)
                      if has_cache else None)
        return x, new_caches, aux_tot

    def body(carry, xs):
        x, aux_tot = carry
        if has_cache:
            up, uc = xs
        else:
            up, uc = xs, None
        x, nc, a = unit(x, up, uc)
        return (x, aux_tot + a), nc if has_cache else None

    xs = (params, caches) if has_cache else params
    (x, aux_tot), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux_tot
