"""Top-level language model: embedding → decoder stack → head → loss.

Entry points (all pure; shapes fixed per (arch × input shape) cell):

  init_params(key, cfg)                         -> params pytree
  forward(params, inputs, cfg, ...)             -> hidden states
  loss_and_aux(params, batch, cfg)              -> scalar loss (chunked xent)
  make_train_step(cfg, lr)                      -> jit-able SGD client step
  make_prefill_step(cfg, batch, seq)            -> serve prefill
  make_decode_step(cfg, batch, seq)             -> serve one-token decode

``input_kind == "embeddings"`` (audio/vlm stubs) feeds precomputed frontend
embeddings of shape (B, S, d_model) instead of token ids; the label side is
always token ids.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.sharding.specs import constrain


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kb, kh = jax.random.split(key, 3)
    p = {
        "embed": layers.embedding_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": transformer.stack_init(kb, cfg),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def _embed_inputs(params, inputs, cfg):
    if cfg.input_kind == "embeddings":
        return inputs.astype(jnp.dtype(cfg.dtype))
    return layers.embed(params["embed"], inputs)


def _head(params, h, cfg):
    if cfg.tie_embeddings:
        return h @ params["embed"]["w"].T
    return layers.dense(params["lm_head"], h)


def forward(params, inputs, cfg, *, positions=None, caches=None,
            cache_index=None, decode=False):
    """inputs: (B,S) ids or (B,S,d) embeddings -> (hidden (B,S,d), caches, aux)."""
    x = constrain(_embed_inputs(params, inputs, cfg), "residual")
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    x, new_caches, aux = transformer.stack_apply(
        params["blocks"], x, cfg, positions=positions, caches=caches,
        cache_index=cache_index, decode=decode)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches, aux


def _xent(logits, labels):
    """Mean token cross-entropy, fp32.  logits: (T,V); labels: (T,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(lse - gold)


def chunked_xent(params, h, labels, cfg):
    """Cross entropy without materialising the full (B, S, V) logits tensor.

    Scans over *sequence* chunks — (nc, B, S/nc, d) — never merging the
    batch and sequence dims, so the (B@dp, S@model) input sharding survives
    the reshape (merging them forces GSPMD into involuntary full
    rematerialisation: a 25.8 GB/device replicated copy on grok-1).  The
    backward pass recomputes each chunk's logits (jax.checkpoint), bounding
    peak memory at (B, S/nc, V/tp) — essential for the 202k-vocab
    llama4-scout cell.
    """
    B, S, d = h.shape
    T = B * S
    chunk_tokens = cfg.logit_chunk or T
    # smallest sequence split nc | S with B * (S/nc) <= logit_chunk
    nc = 1
    while nc < S and (B * (S // nc) > chunk_tokens or S % nc):
        nc += 1
    Sc = S // nc

    @jax.checkpoint
    def one(hc, lc):
        # undo sequence parallelism before the vocab-parallel head: batch
        # over dp, seq replicated, V over model -> no partial-sum all-reduce
        hc = constrain(hc, "loss_chunk")
        logits = _head(params, hc, cfg)
        return _xent(logits.reshape(-1, logits.shape[-1]), lc.reshape(-1))

    if nc == 1:
        return one(h, labels) / T

    hs = jnp.moveaxis(h.reshape(B, nc, Sc, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, Sc), 1, 0)

    def body(tot, xs):
        hc, lc = xs
        return tot + one(hc, lc), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return tot / T


def loss_and_aux(params, batch, cfg):
    """batch: {"inputs": (B,S)[ids]|(B,S,d)[embeds], "labels": (B,S)}."""
    h, _, aux = forward(params, batch["inputs"], cfg)
    loss = chunked_xent(params, h, batch["labels"], cfg)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg, lr: float = 0.05, micro_batches: int = 0):
    """Plain-SGD client local step (the FL inner loop; see core/algorithms
    for the federated wrappers that add proximal terms / control variates).

    ``micro_batches`` > 1 enables gradient accumulation: the global batch is
    scanned in k slices, dividing peak activation memory by ~k at the cost of
    k sequential sub-steps (fp32 accumulator).  Required to fit the biggest
    train cells (grok-1-314b) in 16 GB/chip.
    """
    micro = micro_batches or getattr(cfg, "train_microbatches", 1) or 1

    def train_step(params, batch):
        if micro <= 1:
            loss, grads = jax.value_and_grad(loss_and_aux)(params, batch, cfg)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % micro == 0, (B, micro)
            mb = jax.tree.map(
                lambda a: a.reshape((micro, B // micro) + a.shape[1:]), batch)

            def body(acc, mbatch):
                l, g = jax.value_and_grad(loss_and_aux)(params, mbatch, cfg)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     acc_g, g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(body, zero, mb)
            loss = loss / micro
            grads = jax.tree.map(lambda g: g / micro, grads)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"loss": loss}

    return train_step


def make_prefill_step(cfg, batch: int, seq_len: int, cache_len: int = 0):
    """Full-sequence forward that fills the decode caches.

    ``cache_len`` (>= seq_len) sizes the cache; defaults to seq_len (the
    dry-run convention: decode attends over a cache of exactly seq_len).
    """
    cache_len = cache_len or seq_len

    def prefill_step(params, inputs):
        dtype = jnp.dtype(cfg.dtype)
        caches = transformer.stack_cache(cfg, batch, cache_len, dtype)
        h, new_caches, _ = forward(params, inputs, cfg, caches=caches,
                                   cache_index=0)
        logits = _head(params, h[:, -1:], cfg)
        return logits, new_caches

    return prefill_step


def make_decode_step(cfg):
    """One-token decode against existing caches.

    inputs: token ids (B,1) or embeddings (B,1,d); ``pos``: scalar int32
    (current absolute position).  Returns (logits (B,1,V), new caches).
    """

    def decode_step(params, inputs, caches, pos):
        positions = pos[None] if pos.ndim == 0 else pos
        h, new_caches, _ = forward(params, inputs, cfg, positions=positions,
                                   caches=caches, cache_index=pos, decode=True)
        logits = _head(params, h, cfg)
        return logits, new_caches

    return decode_step
