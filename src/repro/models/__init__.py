from repro.models import attention, layers, lm, moe, ssm, transformer

__all__ = ["attention", "layers", "lm", "moe", "ssm", "transformer"]
