"""State-space / recurrent sequence mixers: Mamba-style SSD and xLSTM blocks.

TPU adaptation note (see DESIGN.md §2): the original Mamba selective scan is a
length-S sequential recurrence designed around GPU shared-memory kernels.  On
TPU we use the *chunked SSD form* (Mamba-2 style): the sequence is split into
chunks of length L; within a chunk the recurrence is evaluated as dense
(L x L)-masked matmuls (MXU-friendly), and a single lax.scan over S/L chunks
carries the inter-chunk state.  The mLSTM uses the same machinery (it is a
gated linear-attention recurrence); the sLSTM is inherently sequential
(hidden-state mixing) and uses a plain lax.scan over time — it only appears in
xlstm-125m where S/step cost is small.

All mixers expose:
  *_init(key, cfg) -> params
  *_apply(params, x, cfg) -> y                       (training / prefill)
  *_step(params, x_t, state, cfg) -> (y_t, state)    (decode)
  *_init_state(cfg, batch, dtype) -> state
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


# ---------------------------------------------------------------------------
# Chunked scalar-decay linear recurrence (shared by SSD and mLSTM)
#
#   h_t = a_t * h_{t-1} + k_t (outer) v_t        h: (N, P)
#   y_t = q_t @ h_t                              q,k: (N,), v: (P,)
# with a_t in (0, 1] a scalar per (batch, head, t).
# ---------------------------------------------------------------------------

def chunked_linear_scan(q, k, v, log_a, h0, chunk: int):
    """q,k: (B,S,H,N); v: (B,S,H,P); log_a: (B,S,H) (<= 0); h0: (B,H,N,P).

    Returns (y: (B,S,H,P), h_final: (B,H,N,P)).  Pure jnp/lax — this is also
    the oracle for the ``ssm_scan`` Pallas kernel.
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    L = min(chunk, S)
    if S % L:
        L = S
    nc = S // L

    qf = q.astype(jnp.float32).reshape(B, nc, L, H, N)
    kf = k.astype(jnp.float32).reshape(B, nc, L, H, N)
    vf = v.astype(jnp.float32).reshape(B, nc, L, H, P)
    la = log_a.astype(jnp.float32).reshape(B, nc, L, H)

    @jax.checkpoint
    def body(h, inp):
        qc, kc, vc, lac = inp          # (B,L,H,N), ..., (B,L,H)
        cum = jnp.cumsum(lac, axis=1)  # inclusive cumulative log decay
        total = cum[:, -1]             # (B,H)
        # intra-chunk: M[t,s] = (q_t . k_s) * exp(cum_t - cum_s) for s <= t
        scores = jnp.einsum("bthn,bshn->bhts", qc, kc)
        decay = cum[:, :, None, :] - cum[:, None, :, :]          # (B,t,s,H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        M = scores * gate.transpose(0, 3, 1, 2)                  # (B,H,t,s)
        y_intra = jnp.einsum("bhts,bshp->bthp", M, vc)
        # inter-chunk: y_t += exp(cum_t) * q_t @ h_prev
        qdec = qc * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bthn,bhnp->bthp", qdec, h)
        # next state: h = exp(total) * h + sum_s exp(total - cum_s) k_s v_s^T
        kdec = kc * jnp.exp(total[:, None] - cum)[..., None]
        h_new = jnp.exp(total)[..., None, None] * h + \
            jnp.einsum("bshn,bshp->bhnp", kdec, vc)
        return h_new, y_intra + y_inter

    inps = (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
            vf.transpose(1, 0, 2, 3, 4), la.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(body, h0.astype(jnp.float32), inps)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y.astype(v.dtype), h_final


def linear_scan_step(q_t, k_t, v_t, a_t, h):
    """Single decode step of the same recurrence.  q_t,k_t: (B,H,N);
    v_t: (B,H,P); a_t: (B,H); h: (B,H,N,P)."""
    h = a_t[..., None, None] * h + \
        k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
    y = jnp.einsum("bhn,bhnp->bhp", q_t.astype(jnp.float32), h)
    return y.astype(v_t.dtype), h


def sequential_linear_scan(q, k, v, log_a, h0):
    """Step-by-step reference for testing the chunked form."""
    B, S, H, N = q.shape

    def body(h, t):
        y, h = linear_scan_step(q[:, t], k[:, t], v[:, t],
                                jnp.exp(log_a[:, t].astype(jnp.float32)), h)
        return h, y

    h, ys = jax.lax.scan(body, h0.astype(jnp.float32), jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h


# ---------------------------------------------------------------------------
# Mamba-style SSD mixer (used by hymba's mamba heads)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg) -> dict:
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.expand * d
    H = sc.n_heads
    N = sc.d_state
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": layers.dense_init(ks[0], d, 2 * di, dtype),
        "conv": (jax.random.normal(ks[1], (sc.d_conv, di), jnp.float32)
                 * (1.0 / np.sqrt(sc.d_conv))).astype(dtype),
        "bc_proj": layers.dense_init(ks[2], di, 2 * N, dtype),
        "dt_proj": layers.dense_init(ks[3], di, H, dtype, bias=True),
        "out_proj": layers.dense_init(ks[4], di, d, dtype),
        # A < 0 per head; D skip per head
        "log_neg_a": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
    }
    return p


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B,S,di); w: (K,di).
    If state (B,K-1,di) is given, runs in streaming mode and returns
    (y, new_state); else pads with zeros."""
    K = w.shape[0]
    if state is not None:
        xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xx[:, -(K - 1):] if K > 1 else state
    else:
        xx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    y = sum(xx[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return y, new_state


def _mamba_qkva(params, x, cfg):
    """Shared projection logic.  x: (B,S,d) -> q,k,v,log_a,z and di pieces."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    H, N = sc.n_heads, sc.d_state
    P = di // H
    u = layers.dense(params["in_proj"], x)
    xs, z = jnp.split(u, 2, axis=-1)
    return xs, z, (H, N, P)


def mamba_apply(params, x, cfg, conv_state=None, h0=None):
    """x: (B,S,d) -> (y, (conv_state, h_final))."""
    sc = cfg.ssm
    B, S, _ = x.shape
    xs, z, (H, N, P) = _mamba_qkva(params, x, cfg)
    xc, new_conv = _causal_conv(xs, params["conv"], conv_state)
    xc = jax.nn.silu(xc)
    bc = layers.dense(params["bc_proj"], xc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                       # (B,S,N) each
    dt = jax.nn.softplus(layers.dense(params["dt_proj"], xc).astype(jnp.float32))
    A = -jnp.exp(params["log_neg_a"])                        # (H,) < 0
    log_a = dt * A                                           # (B,S,H)
    v = xc.reshape(B, S, H, P) * dt[..., None].astype(xc.dtype)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    y, h_final = chunked_linear_scan(q, k, v, log_a, h0, sc.chunk_size)
    y = y + xc.reshape(B, S, H, P) * params["d_skip"][None, None, :, None].astype(xc.dtype)
    y = y.reshape(B, S, H * P) * jax.nn.silu(z)
    return layers.dense(params["out_proj"], y), (new_conv, h_final)


def mamba_init_state(cfg, batch: int, dtype):
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    H, N, P = sc.n_heads, sc.d_state, di // sc.n_heads
    return {
        "conv": jnp.zeros((batch, sc.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_step(params, x_t, state, cfg):
    """x_t: (B,1,d) decode step -> (y_t (B,1,d), new state)."""
    y, (conv, h) = mamba_apply(params, x_t, cfg,
                               conv_state=state["conv"], h0=state["h"])
    return y, {"conv": conv, "h": h}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (chunked) and sLSTM (sequential) blocks
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg) -> dict:
    xc = cfg.xlstm
    d = cfg.d_model
    di = xc.mlstm_expand * d
    H = cfg.n_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "up": layers.dense_init(ks[0], d, 2 * di, dtype),
        "wq": layers.dense_init(ks[1], di, di, dtype),
        "wk": layers.dense_init(ks[2], di, di, dtype),
        "wv": layers.dense_init(ks[3], di, di, dtype),
        "wi": layers.dense_init(ks[4], di, H, dtype, bias=True),
        "wf": layers.dense_init(ks[5], di, H, dtype, bias=True),
        "down": layers.dense_init(ks[6], di, d, dtype),
    }


def _mlstm_core(params, xs, cfg, h0):
    """xs: (B,S,di).  Returns (y (B,S,di), h_final)."""
    xc = cfg.xlstm
    B, S, di = xs.shape
    H = cfg.n_heads
    P = di // H
    q = layers.dense(params["wq"], xs).reshape(B, S, H, P)
    k = layers.dense(params["wk"], xs).reshape(B, S, H, P) / np.sqrt(P)
    v = layers.dense(params["wv"], xs).reshape(B, S, H, P)
    # exponential-family gates kept in (0,1) via log-sigmoid for stability
    log_f = jax.nn.log_sigmoid(
        layers.dense(params["wf"], xs).astype(jnp.float32))      # (B,S,H)
    i_gate = jnp.exp(jax.nn.log_sigmoid(
        layers.dense(params["wi"], xs).astype(jnp.float32)))
    kg = k * i_gate[..., None].astype(k.dtype)
    # append a ones-channel to v to carry the normaliser n_t
    v1 = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y1, h_final = chunked_linear_scan(q, kg, v1, log_f, h0, xc.chunk_size)
    y, n = y1[..., :P], y1[..., P:]
    y = y / jnp.maximum(jnp.abs(n), 1.0).astype(y.dtype)
    return y.reshape(B, S, di), h_final


def mlstm_apply(params, x, cfg, h0=None):
    xc = cfg.xlstm
    B, S, d = x.shape
    di = xc.mlstm_expand * d
    H, P = cfg.n_heads, di // cfg.n_heads
    u = layers.dense(params["up"], x)
    xs, z = jnp.split(u, 2, axis=-1)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, P + 1), jnp.float32)
    y, h_final = _mlstm_core(params, xs, cfg, h0)
    y = y * jax.nn.silu(z)
    return layers.dense(params["down"], y), h_final


def mlstm_init_state(cfg, batch: int, dtype):
    xc = cfg.xlstm
    di = xc.mlstm_expand * cfg.d_model
    H, P = cfg.n_heads, di // cfg.n_heads
    return {"h": jnp.zeros((batch, H, P, P + 1), jnp.float32)}


def mlstm_step(params, x_t, state, cfg):
    y, h = mlstm_apply(params, x_t, cfg, h0=state["h"])
    return y, {"h": h}


def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input and recurrent hidden state
        "wx": layers.dense_init(ks[0], d, 4 * d, dtype, bias=True),
        "wh": layers.dense_init(ks[1], d, 4 * d, dtype),
        "out": layers.dense_init(ks[2], d, d, dtype),
    }


def slstm_init_state(cfg, batch: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_cell(params, x_t, st):
    """x_t: (B,d).  Stabilised exponential-gating sLSTM cell."""
    gates = (layers.dense(params["wx"], x_t).astype(jnp.float32) +
             st["h"] @ params["wh"]["w"].astype(jnp.float32))
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + st["m"], gi)                 # stabiliser
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(log_f + st["m"] - m_new)
    c = f_p * st["c"] + i_p * jnp.tanh(gz)
    n = f_p * st["n"] + i_p
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(params, x, cfg, state=None):
    """Chunked-remat BPTT: the step scan is wrapped in jax.checkpoint per
    time-chunk, so the backward pass stores only per-chunk carries instead
    of per-step gates — S/chunk × less activation memory for one extra
    forward (§Perf iteration 2: 4096-step xlstm BPTT was the memory-bound
    worst cell of the roofline table)."""
    B, S, d = x.shape
    st = state or slstm_init_state(cfg, B, x.dtype)
    Tc = (cfg.xlstm.chunk_size if cfg.xlstm else 256)
    if S % Tc or S <= Tc:
        Tc = S
    nc = S // Tc

    def step(st, x_t):
        st = _slstm_cell(params, x_t, st)
        return st, st["h"]

    @jax.checkpoint
    def chunk(st, xc):                       # xc: (Tc, B, d)
        return jax.lax.scan(step, st, xc)

    xs = x.transpose(1, 0, 2).reshape(nc, Tc, B, d)
    st, hs = jax.lax.scan(chunk, st, xs)
    y = hs.reshape(S, B, d).transpose(1, 0, 2).astype(x.dtype)
    return layers.dense(params["out"], y), st


def slstm_step(params, x_t, state, cfg):
    """x_t: (B,1,d)."""
    st = _slstm_cell(params, x_t[:, 0], state)
    y = layers.dense(params["out"], st["h"].astype(x_t.dtype))
    return y[:, None], st
