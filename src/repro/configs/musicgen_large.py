"""musicgen-large [audio] — decoder-only LM over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048  [arXiv:2306.05284; hf]
The EnCodec frontend is a stub: ``input_specs()`` supplies precomputed frame
embeddings (B, S, d_model); the decoder backbone is what is modelled here.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    input_kind="embeddings",
    logit_chunk=32768,
)
