from repro.configs.base import (ALL_SHAPES, FLConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SSMConfig, XLSTMConfig,
                                shape_by_name)

__all__ = [
    "ALL_SHAPES", "FLConfig", "ModelConfig", "MoEConfig", "ShapeConfig",
    "SSMConfig", "XLSTMConfig", "shape_by_name",
]
