"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]
The CLIP vision tower is a stub per the assignment: ``input_specs()`` feeds
precomputed patch embeddings interleaved with text embeddings as (B,S,d).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    input_kind="embeddings",
    logit_chunk=32768,
)
