"""Configuration dataclasses for models, shapes, meshes and FL runs.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances.  Full configs are
exercised only through the dry-run (``launch/dryrun.py``); smoke tests call
``reduced()`` to obtain a tiny same-family config that runs on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Tokens are dispatched in groups of this many; the dispatch/combine
    # einsums are O(group_size * n_experts * capacity) per group.
    group_size: int = 4096
    # "gshard_einsum" (SPMD-safe one-hot dispatch) or "gather" (index based,
    # cheaper FLOPs — used by the perf hillclimb).
    dispatch_impl: str = "gshard_einsum"
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    n_heads: int = 8            # SSD heads (mamba2-style scalar-decay heads)
    chunk_size: int = 256       # chunk length for the SSD chunked scan


@dataclass(frozen=True)
class XLSTMConfig:
    # Alternating block pattern, e.g. ("mlstm", "slstm") repeated.
    pattern: Tuple[str, ...] = ("mlstm", "slstm")
    mlstm_expand: int = 2
    slstm_n_heads: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0     # 0 -> full attention
    attention_impl: str = "chunked"   # dense | chunked | pallas
    attn_chunk: int = 512       # kv-chunk for the online-softmax reference
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # "tokens" -> int ids; "embeddings" -> precomputed frontend embeddings
    # (audio frames / vision patches are stubs per the assignment).
    input_kind: str = "tokens"
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logit_chunk: int = 0        # 0 -> unchunked loss; >0 -> chunked xent
    train_microbatches: int = 1  # gradient accumulation for train shapes

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_recurrent(self) -> bool:
        """True when decode state is O(1) in context length (SSM/xLSTM/hybrid
        with sliding window) — required for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += d * V
        per_layer = 0
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        if self.family == "ssm":  # xLSTM
            xc = self.xlstm or XLSTMConfig()
            di = xc.mlstm_expand * d
            # mLSTM: up/gate proj (2*d*di), q/k/v (3*di*di), out (di*d), gates
            mlstm = 2 * d * di + 3 * di * di + di * d + 3 * di
            # sLSTM: 4 gates input + recurrent per head + out
            slstm = 4 * d * d + 4 * d * d + d * d
            n += (L // 2) * (mlstm + slstm) + (L % 2) * mlstm
            n += 2 * L * d  # norms
            return n
        # attention part
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.qkv_bias:
            attn += H * hd + 2 * KV * hd
        per_layer += attn
        if self.family == "hybrid":
            sc = self.ssm or SSMConfig()
            di = sc.expand * d
            per_layer += d * 2 * di + di * d + di * (2 * sc.d_state) + di
        if self.moe is not None:
            per_layer += d * self.moe.n_experts            # router
            per_layer += self.moe.n_experts * 3 * d * f    # swiglu experts
        elif f > 0:
            per_layer += 3 * d * f
        per_layer += 2 * d  # norms
        n += L * per_layer + d  # final norm
        return n

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        dense_experts = self.n_layers * m.n_experts * 3 * self.d_model * self.d_ff
        active_experts = self.n_layers * m.top_k * 3 * self.d_model * self.d_ff
        return self.n_params() - dense_experts + active_experts

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            head_dim=16,
            sliding_window=32 if self.sliding_window else 0,
            attn_chunk=32,
            dtype="float32",
            remat=False,
            logit_chunk=0,
            train_microbatches=1,
        )
        if self.moe is not None:
            # capacity_factor=4 -> drop-free routing, so smoke tests can
            # compare prefill/decode against the full forward exactly.
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=self.moe.top_k, group_size=64,
                capacity_factor=4.0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=8, n_heads=2, chunk_size=16)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, chunk_size=16,
                                              slstm_n_heads=2)
        return dataclasses.replace(self, **kw)


def hd_safe(d: int, h: int) -> int:
    return d // h


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode | long_decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "long_decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclass(frozen=True)
class FLConfig:
    """Parrot federated-learning round configuration."""
    n_clients: int = 1000              # M
    clients_per_round: int = 100       # M_p
    n_executors: int = 8               # K
    local_epochs: int = 1              # E
    local_batch_size: int = 20
    client_lr: float = 0.05
    server_lr: float = 1.0
    algorithm: str = "fedavg"
    scheduler: str = "parrot"          # parrot | uniform | none
    time_window: int = 0               # tau; 0 -> all history
    warmup_rounds: int = 1             # R_w: uniform scheduling warmup
    seed: int = 0
    partition: str = "natural"         # natural | dirichlet | quantity_skew
    partition_arg: float = 0.1
    compression: str = "none"          # none | topk | int8
    compression_arg: float = 0.01
