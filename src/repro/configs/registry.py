"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (ALL_SHAPES, ModelConfig, ShapeConfig,
                                shape_by_name)
from repro.configs import (grok1_314b, hymba_1_5b, llama3_2_3b,
                           llama4_scout_17b_a16e, musicgen_large,
                           phi3_mini_3_8b, phi3_vision_4_2b, qwen2_0_5b,
                           qwen2_5_14b, xlstm_125m)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        musicgen_large.CONFIG,
        phi3_mini_3_8b.CONFIG,
        qwen2_0_5b.CONFIG,
        llama3_2_3b.CONFIG,
        qwen2_5_14b.CONFIG,
        phi3_vision_4_2b.CONFIG,
        grok1_314b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        hymba_1_5b.CONFIG,
        xlstm_125m.CONFIG,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) dry-run cell applies (DESIGN.md §Shape)."""
    if shape.kind == "long_decode" and not cfg.is_recurrent:
        return False, ("skipped: pure full-attention arch has no sub-quadratic "
                       "path for 524k context (DESIGN.md §Shape handling)")
    return True, ""


def all_cells():
    for name, cfg in ARCHS.items():
        for shape in ALL_SHAPES:
            ok, why = cell_is_runnable(cfg, shape)
            yield name, cfg, shape, ok, why
