"""hymba-1.5b [hybrid] — parallel attention + mamba heads, sliding window.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]
Each block runs GQA attention and SSD(mamba) heads in parallel on the same
normalised input and fuses by averaging (the Hymba "parallel heads" design).
Sliding-window attention + O(1) SSM state make long_500k decode runnable.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, n_heads=8, chunk_size=256),
    logit_chunk=32768,
)
