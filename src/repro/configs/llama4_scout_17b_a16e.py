"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E]
Early-fusion multimodal frontend is a stub (precomputed embeddings).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=1, group_size=4096),
    input_kind="embeddings",
    train_microbatches=4,
    logit_chunk=8192,
)
