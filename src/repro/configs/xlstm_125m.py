"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks.

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517]
d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM expand=2);
no separate FFN.  Fully recurrent -> long_500k decode is O(1) state.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    xlstm=XLSTMConfig(pattern=("mlstm", "slstm"), mlstm_expand=2,
                      slstm_n_heads=4, chunk_size=256),
    logit_chunk=32768,
)
