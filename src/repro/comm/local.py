"""In-process Communicator used by the simulation (and the unit tests).

Messages are passed by reference (zero-copy, like executors sharing a host)
but *accounted* at their serialised size, so the comm-complexity benchmarks
measure exactly what a networked transport would move.

Device residency (DESIGN.md §8): because messages move by reference, a
device-resident flat partial from a pinned executor reaches the server-side
fold as the SAME buffers, still committed to the executor's device — no
host round-trip, no copy, and no sync (the byte accounting reads shapes and
dtypes only, never values).  Cross-device placement happens exactly once,
inside the sharded/colocating global fold.
"""
from __future__ import annotations

import collections
import queue
from typing import Any, Dict, List, Tuple

from repro.comm.base import Communicator


def _nbytes(payload: Any) -> int:
    # lazy import: repro.core.round imports this module (cycle otherwise).
    # wire_bytes counts a compressed partial at its achieved wire size (the
    # sums' compressed segments + the uncompressed rest) — the same sizing
    # the network model prices uploads at (core/network.py).
    from repro.core.aggregation import wire_bytes
    try:
        return wire_bytes(payload)
    except Exception:
        return 0


class LocalComm(Communicator):
    def __init__(self):
        super().__init__()
        self._to_exec: Dict[Tuple[int, str], "queue.Queue"] = \
            collections.defaultdict(queue.Queue)
        self._to_server: Dict[Tuple[int, str], "queue.Queue"] = \
            collections.defaultdict(queue.Queue)

    def broadcast(self, payload, executors, tag):
        nb = _nbytes(payload)
        for k in executors:
            self._to_exec[(k, tag)].put(payload)
        # one logical trip per executor (server pushes K messages)
        self.stats.add(tag, nb * len(executors), trips=len(executors))

    def send_to_executor(self, executor, payload, tag):
        self._to_exec[(executor, tag)].put(payload)
        self.stats.add(tag, _nbytes(payload), trips=1)

    def recv_from_executor(self, executor, tag):
        return self._to_server[(executor, tag)].get()

    def executor_send(self, executor, payload, tag):
        self._to_server[(executor, tag)].put(payload)
        self.stats.add(tag, _nbytes(payload), trips=1)

    def executor_recv(self, executor, tag):
        return self._to_exec[(executor, tag)].get()

    def poll(self, executor, tag):
        try:
            return self._to_server[(executor, tag)].get_nowait()
        except queue.Empty:
            return None
