from repro.comm.base import CommStats, Communicator
from repro.comm.local import LocalComm

__all__ = ["CommStats", "Communicator", "LocalComm"]
