"""Abstract communication layer (paper §3.2, "Easy Migration").

FL algorithm code never touches a transport directly: the round engine talks
to a :class:`Communicator`, and swapping the implementation moves the same
code between (a) in-process simulation (:class:`LocalComm`), (b) SPMD
collectives on a TPU mesh (:class:`CollectiveComm` in ``collective.py``), and
(c) a real cross-silo deployment (a gRPC/MQTT transport would implement the
same five methods) — the paper's zero-code-change migration claim.

Every implementation records :class:`CommStats` (bytes and trips per round),
which is how the Table-1 communication-complexity benchmark measures the
hierarchical-aggregation saving.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Dict, List


@dataclass
class CommStats:
    bytes_sent: int = 0
    bytes_received: int = 0
    trips: int = 0
    by_tag: Dict[str, int] = field(default_factory=dict)

    def add(self, tag: str, nbytes: int, trips: int = 1) -> None:
        self.bytes_sent += nbytes
        self.trips += trips
        self.by_tag[tag] = self.by_tag.get(tag, 0) + nbytes

    def reset(self) -> "CommStats":
        snap = CommStats(self.bytes_sent, self.bytes_received, self.trips,
                         dict(self.by_tag))
        self.bytes_sent = self.bytes_received = self.trips = 0
        self.by_tag = {}
        return snap


class Communicator(abc.ABC):
    """Server <-> executor transport."""

    def __init__(self):
        self.stats = CommStats()

    @abc.abstractmethod
    def broadcast(self, payload: Any, executors: List[int], tag: str) -> None:
        """Server -> all executors (Θ^r and the task lists)."""

    @abc.abstractmethod
    def send_to_executor(self, executor: int, payload: Any, tag: str) -> None:
        """Server -> one executor."""

    @abc.abstractmethod
    def recv_from_executor(self, executor: int, tag: str) -> Any:
        """Server <- one executor (the partial aggregate G_k: one trip)."""

    @abc.abstractmethod
    def executor_send(self, executor: int, payload: Any, tag: str) -> None:
        """Executor -> server."""

    @abc.abstractmethod
    def executor_recv(self, executor: int, tag: str) -> Any:
        """Executor <- server."""

    @abc.abstractmethod
    def poll(self, executor: int, tag: str) -> Any:
        """Non-blocking server <- executor receive.

        Returns the oldest pending ``executor_send`` payload for ``(executor,
        tag)`` and consumes it, or ``None`` when nothing has landed yet.  The
        ``executor_send`` / ``poll`` pair is the transport contract of the
        event-driven round engines (DESIGN.md §3): executors push per-chunk
        partials as they complete, the server drains them whenever the event
        loop gives it control — no blocking rendezvous, so a straggler can
        never stall the fold path.
        """
