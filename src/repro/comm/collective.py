"""Collective (SPMD) realisation of the hierarchical global aggregate.

In production the K executors are mesh slices of a TPU pod, and
``GlobalAggregate`` (Algorithm 2) is not a message exchange at all but ONE
``psum`` over the data-parallel axes — the TPU-native form of the paper's
"K communication trips" (DESIGN.md §2).  On the 2-pod mesh XLA decomposes
the psum hierarchically (intra-pod reduce-scatter over ICI, inter-pod
all-reduce over DCI), which is the paper's local→global idea applied one
level deeper.

``spmd_global_aggregate`` takes the per-executor partials stacked on the
leading axis, shards them over a mesh axis, and reduces with a single
collective; it matches ``aggregation.global_aggregate`` exactly (tested).
The device-placement layer (``core/placement.py``) realises the same idea
for device-pinned executors without ever host-gathering: per-device partial
buffers are assembled zero-copy into one sharded array and reduced with a
single ``shard_map``/``psum`` per weight group.  ``CollectiveComm`` keeps
payloads in its inbox by reference, so device-resident buffers ship without
a host round-trip here too.
Flat-buffer partials (the ``LocalAggregator`` wire format) reduce even
better: ONE collective per weight group — the whole multi-entry partial is
a single contiguous (n,) buffer — instead of one per entry/leaf.
``CollectiveComm`` adapts the same mechanism to the Communicator interface
so the round engine can swap transports without code changes.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.comm.base import Communicator


def _payload_bytes(x):
    # lazy import (repro.core.round -> repro.comm: cycle otherwise)
    from repro.core.aggregation import payload_bytes
    return payload_bytes(x)


def _stack_partials(partials: List[Dict], name: str):
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[p["sums"][name] for p in partials])


def spmd_global_aggregate(partials: List[Dict], ops: Dict[str, Any],
                          mesh=None, axis: str = "data") -> Dict[str, Any]:
    """GlobalAggregate as one sharded reduction per entry.

    partials: the K executor partials.  When a mesh is given and K divides
    the axis, the stacked partials are laid out over it and the reduction
    lowers to a single all-reduce; otherwise it runs as a local sum (the
    K=devices degenerate case — same math either way).
    """
    from repro.core.aggregation import Op, reduce_flat_partials
    from repro.core.flat import is_flat_partial
    K = len(partials)

    if partials and all(is_flat_partial(p) for p in partials):
        # flat wire format: one sharded reduction per weight group covers
        # every reducible entry at once
        def reduce_group(bufs):
            from repro.sharding.specs import stacked_partial_spec
            x = jnp.stack(bufs)
            if mesh is not None and len(bufs) % mesh.shape[axis] == 0:
                # the caller's single reduction axis, NOT all dp axes: the
                # divisibility guard above only checks `axis` (multi-pod
                # meshes reduce pod-locally here)
                x = jax.device_put(x, NamedSharding(
                    mesh, stacked_partial_spec(mesh, axes=(axis,))))
            return jnp.sum(x, axis=0)

        return reduce_flat_partials(partials, ops, reduce_group)

    out: Dict[str, Any] = {}
    for name, op in ops.items():
        if op is Op.COLLECT:
            coll: List[Any] = []
            for p in partials:
                coll.extend(p["collected"].get(name, []))
            out[name] = coll
            continue
        if not any(name in p["sums"] for p in partials):
            continue
        stacked = _stack_partials(partials, name)   # leaves: (K, ...)

        def reduce_leaf(x):
            if mesh is not None and K % mesh.shape[axis] == 0:
                x = jax.device_put(
                    x, NamedSharding(mesh, P(axis, *([None] * (x.ndim - 1)))))
            return jnp.sum(x, axis=0)

        total = jax.tree.map(reduce_leaf, stacked)
        if op is Op.SUM:
            out[name] = total
        elif op is Op.AVG:
            n = sum(p["counts"].get(name, 0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(n, 1), total)
        else:  # WEIGHTED_AVG
            wtot = sum(p["weights"].get(name, 0.0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(wtot, 1e-12), total)
    return out


class CollectiveComm(Communicator):
    """Communicator whose server-side recv path runs the SPMD aggregate.

    Broadcast is a device_put with a replicated sharding (XLA broadcasts
    over the mesh); executor partials are accounted at the bytes one psum
    moves per device (2·(n-1)/n · s_a ≈ 2·s_a), NOT K·s_a — the wire-level
    expression of the paper's Table-1 saving.
    """

    def __init__(self, mesh=None):
        super().__init__()
        self.mesh = mesh
        self._inbox: Dict[tuple, Any] = {}

    def broadcast(self, payload, executors, tag):
        nb = _payload_bytes(payload)
        if self.mesh is not None:
            payload = jax.device_put(
                payload, NamedSharding(self.mesh,
                                       P(*([None]))))
        for k in executors:
            self._inbox[(k, tag)] = payload
        self.stats.add(tag, nb, trips=1)      # one replicated push

    def send_to_executor(self, executor, payload, tag):
        self._inbox[(executor, tag)] = payload
        self.stats.add(tag, _payload_bytes(payload), trips=1)

    def recv_from_executor(self, executor, tag):
        return self._inbox.pop(("srv", executor, tag))

    def executor_send(self, executor, payload, tag):
        self._inbox[("srv", executor, tag)] = payload
        # psum wire cost per device ~ 2 x payload, independent of K
        self.stats.add(tag, 2 * _payload_bytes(payload.get("sums", payload))
                       if isinstance(payload, dict) else
                       2 * _payload_bytes(payload), trips=1)

    def executor_recv(self, executor, tag):
        return self._inbox.pop((executor, tag))

    def poll(self, executor, tag):
        # the inbox holds at most one in-flight payload per (executor, tag):
        # the engines drain each chunk partial before the executor's next
        # chunk is dispatched, so a single slot is enough
        return self._inbox.pop(("srv", executor, tag), None)
