from repro.optim.optimizers import (adamw, fedadam, fedavgm, fedyogi, sgd,
                                    ServerOptimizer)

__all__ = ["adamw", "fedadam", "fedavgm", "fedyogi", "sgd", "ServerOptimizer"]
