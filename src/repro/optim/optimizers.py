"""Optimizers: client-side SGD(+momentum)/AdamW and server-side federated
optimizers (Reddi et al., 2021 — FedAvgM / FedAdam / FedYogi).

Functional style: ``init(params) -> state``; ``update(grads, state, params)
-> (updates, state)``; apply with ``apply_updates``.  Server optimizers treat
the aggregated client delta as a pseudo-gradient.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Any]
    update: Callable[[Pytree, Any, Pytree], Tuple[Pytree, Any]]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)),
                new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
        upd = jax.tree.map(
            lambda mh, vh, p: -lr * (mh / (jnp.sqrt(vh) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# server optimizers (pseudo-gradient = aggregated delta)
# ---------------------------------------------------------------------------

class ServerOptimizer:
    """Wraps an Optimizer so FL server updates are ``params ⊕ opt(-delta)``
    (delta is a descent *step*, so the pseudo-gradient is its negation)."""

    def __init__(self, opt: Optimizer):
        self.opt = opt
        self.state = None

    def init(self, params):
        self.state = self.opt.init(params)
        return self.state

    def step(self, params, delta):
        pseudo_grad = jax.tree.map(lambda d: -d, delta)
        upd, self.state = self.opt.update(pseudo_grad, self.state, params)
        return apply_updates(params, upd)


def fedavgm(lr: float = 1.0, momentum: float = 0.9) -> ServerOptimizer:
    return ServerOptimizer(sgd(lr, momentum=momentum))


def fedadam(lr: float = 0.01, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> ServerOptimizer:
    return ServerOptimizer(adamw(lr, b1, b2, eps))


def fedyogi(lr: float = 0.01, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> ServerOptimizer:
    base = adamw(lr, b1, b2, eps)

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        # yogi: v grows only toward g^2 (sign-controlled)
        v = jax.tree.map(
            lambda v, g: v - (1 - b2) * jnp.square(g.astype(jnp.float32))
            * jnp.sign(v - jnp.square(g.astype(jnp.float32))),
            state["v"], grads)
        upd = jax.tree.map(lambda m, v: -lr * m / (jnp.sqrt(jnp.abs(v)) + eps),
                           m, v)
        return upd, {"m": m, "v": v, "t": t}

    return ServerOptimizer(Optimizer(base.init, update))
