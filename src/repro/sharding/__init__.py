from repro.sharding.specs import (batch_spec, cache_spec, caches_shardings,
                                  constrain, dp_axes, enable_activation_policy,
                                  param_spec, params_shardings)

__all__ = ["batch_spec", "cache_spec", "caches_shardings", "constrain",
           "dp_axes", "enable_activation_policy", "param_spec",
           "params_shardings"]
