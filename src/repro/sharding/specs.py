"""Sharding rules: parameter / activation / cache PartitionSpecs.

Strategy (DESIGN.md §5): FSDP×TP 2-D sharding.
- Every large weight shards its biggest eligible dim over the data-parallel
  axes (``("pod","data")`` multi-pod, ``("data",)`` single-pod — ZeRO-3
  style, XLA inserts the all-gathers) and a second dim over ``"model"``
  (Megatron TP).
- Rules are *name-aware* where structure matters (embeddings, attention,
  MoE experts, KV caches) and fall back to a size heuristic for anything
  else, so new substrates inherit a sane sharding without edits here.
- Stacked-layer params (leading ``n_rep`` dim from scan-over-layers) get
  ``None`` for the layer dim automatically.

All functions return ``PartitionSpec``; callers wrap in ``NamedSharding``
with the production mesh.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def stacked_partial_spec(mesh, ndim: int = 2,
                         axes: Optional[Sequence[str]] = None) -> P:
    """PartitionSpec for per-executor flat partials stacked on axis 0 —
    rows over the data-parallel axes (or an explicit ``axes`` subset, e.g.
    a single-axis reduction on a multi-pod mesh), buffer payload unsharded.
    Shared by the placement layer's psum fold (one (1, n) shard per device)
    and the SPMD collective aggregate, so the two reductions cannot drift
    onto different layouts."""
    row = tuple(axes) if axes is not None else dp_axes(mesh)
    return P(row, *([None] * (ndim - 1)))


def axis_size(mesh, axes) -> int:
    n = 1
    for a in ([axes] if isinstance(axes, str) else axes):
        n *= mesh.shape[a]
    return n


def _divisible(dim: int, n: int) -> bool:
    return dim > 0 and dim % n == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(path, shape: Tuple[int, ...], mesh,
               stacked: bool = True, tied_embeddings: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``stacked``: model params carry a leading layer dim (scan-over-layers);
    it is detected per-leaf by name (block params live under 'blocks').
    ``tied_embeddings``: the embedding doubles as the LM head, so it gets the
    Megatron vocab-parallel layout (V over model, d over dp) — otherwise the
    tied head matmul contracts a model-sharded d and all-reduces full logits
    every xent chunk (observed: 2×8e10 B/device on qwen2-0.5b train_4k).
    """
    name = _path_str(path)
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)
    ntp = mesh.shape["model"]
    is_stacked = stacked and "blocks" in name
    dims = list(shape[1:]) if is_stacked else list(shape)
    off = 1 if is_stacked else 0

    spec: list = [None] * len(shape)

    def assign(local_idx: int, axes) -> None:
        spec[local_idx + off] = axes

    small = int(np.prod(dims)) <= 4096 if dims else True

    if not dims or small:
        pass                                            # replicate
    elif len(dims) == 1:
        if _divisible(dims[0], ntp) and dims[0] >= 8192:
            assign(0, "model")
    else:
        # name-aware fast paths ------------------------------------------
        lowered = name.lower()
        handled = True
        if re.search(r"embed/w$", lowered) and len(dims) == 2:
            if tied_embeddings:
                # vocab-parallel: V over model, d over dp
                if _divisible(dims[0], ntp):
                    assign(0, "model")
                if _divisible(dims[1], ndp):
                    assign(1, dp)
            else:
                # (V, d): vocab over dp (ZeRO), d over model
                if _divisible(dims[0], ndp):
                    assign(0, dp)
                if _divisible(dims[1], ntp):
                    assign(1, "model")
        elif re.search(r"lm_head/w$", lowered) and len(dims) == 2:
            # (d, V): d over dp, vocab over model (column-parallel head)
            if _divisible(dims[0], ndp):
                assign(0, dp)
            if _divisible(dims[1], ntp):
                assign(1, "model")
        elif re.search(r"attn/(wq|wk|wv)/(w|b)$", lowered):
            # (d, Hn, hd) / bias (Hn, hd): heads over model when divisible
            # (classic TP); otherwise replicate over model and the activation
            # policy falls back to sequence-TP.  d over dp (ZeRO).
            h_dim = len(dims) - 2
            if _divisible(dims[h_dim], ntp):
                assign(h_dim, "model")
            if len(dims) == 3 and _divisible(dims[0], ndp):
                assign(0, dp)
        elif re.search(r"attn/wo/w$", lowered) and len(dims) == 3:
            # (H, hd, d): heads over model (row-parallel), d over dp
            if _divisible(dims[0], ntp):
                assign(0, "model")
            if _divisible(dims[2], ndp):
                assign(2, dp)
        elif re.search(r"ffn/(wi|wg)/?w?$", lowered) and len(dims) == 3:
            # MoE experts (E, d, f): d over dp (ZeRO storage), f over model;
            # the use-site gathers d explicitly (constrain "moe_weight") so
            # the backward reduce-scatters weight grads instead of
            # all-reducing (G,E,C,d) activation buffers (§Perf iteration 3)
            if _divisible(dims[1], ndp):
                assign(1, dp)
            if _divisible(dims[2], ntp):
                assign(2, "model")
        elif re.search(r"ffn/wo/?w?$", lowered) and len(dims) == 3:
            # MoE experts (E, f, d): f over model (row-parallel: matches the
            # act's f@model), d over dp (ZeRO)
            if _divisible(dims[1], ntp):
                assign(1, "model")
            if _divisible(dims[2], ndp):
                assign(2, dp)
        elif len(dims) == 2 and re.search(
                r"/(wo|down|out_proj|out)/w$", lowered):
            # second matmul of a block (row-parallel): in-dim over model
            if _divisible(dims[0], ntp):
                assign(0, "model")
            if _divisible(dims[1], ndp):
                assign(1, dp)
        elif len(dims) == 2:
            # first matmul (column-parallel): in over dp, out over model
            if _divisible(dims[0], ndp):
                assign(0, dp)
            if _divisible(dims[1], ntp):
                assign(1, "model")
        else:
            handled = False
        if not handled:
            # generic heuristic: biggest divisible dim -> dp, next -> tp
            order = sorted(range(len(dims)), key=lambda i: -dims[i])
            dp_dim = next((i for i in order if _divisible(dims[i], ndp)), None)
            if dp_dim is not None:
                assign(dp_dim, dp)
            tp_dim = next((i for i in order
                           if i != dp_dim and _divisible(dims[i], ntp)), None)
            if tp_dim is not None:
                assign(tp_dim, "model")
    return P(*spec)


def params_shardings(params_shape_tree, mesh):
    """NamedSharding tree matching a params ShapeDtypeStruct tree."""
    from jax.sharding import NamedSharding

    tied = isinstance(params_shape_tree, dict) and \
        "lm_head" not in params_shape_tree

    def leaf(path, leaf):
        return NamedSharding(
            mesh, param_spec(path, leaf.shape, mesh, tied_embeddings=tied))

    return jax.tree_util.tree_map_with_path(leaf, params_shape_tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(shape: Tuple[int, ...], mesh, seq_axis: Optional[int] = None) -> P:
    """Inputs/labels (B, S[, d]): batch over dp; if batch=1 (long-context)
    shard the sequence dim over dp instead (sequence parallelism)."""
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)
    spec: list = [None] * len(shape)
    if _divisible(shape[0], ndp):
        spec[0] = dp
    elif len(shape) > 1 and seq_axis is not None \
            and _divisible(shape[seq_axis], ndp):
        spec[seq_axis] = dp
    return P(*spec)


def cache_spec(path, shape: Tuple[int, ...], mesh) -> P:
    """KV-cache / decode-state leaves (stacked: leading n_rep dim).

    k/v: (L, B, Smax, KV, hd) — batch over dp; kv-heads over model when
    divisible, else head_dim, else Smax.  pos: replicated.  SSM states
    (L, B, H, N, P): batch over dp, heads/P over model.
    """
    name = _path_str(path)
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)
    ntp = mesh.shape["model"]
    spec: list = [None] * len(shape)
    if name.endswith("pos") or len(shape) < 3:
        return P(*spec)
    # dims[0] = layer stack; dims[1] = batch
    if _divisible(shape[1], ndp):
        spec[1] = dp
    if re.search(r"attn/(k|v)$", name) and len(shape) == 5:
        # (L, B, Smax, KV, hd): prefer kv-heads over model (no comm on the
        # score einsum); else the ring-buffer seq dim (sharded cache, softmax
        # stats reduced over model); else head_dim (contraction all-reduce).
        for i in (3, 2, 4):
            if _divisible(shape[i], ntp) and shape[i] >= ntp:
                spec[i] = "model"
                break
    else:
        # SSM/conv decode states: model axis on the largest divisible
        # trailing dim
        for i in range(len(shape) - 1, 1, -1):
            if spec[i] is None and _divisible(shape[i], ntp) and shape[i] >= ntp:
                spec[i] = "model"
                break
    if all(s is None for s in spec[1:]) and _divisible(shape[2], ndp):
        spec[2] = dp   # batch=1 long-context: shard the ring buffer seq dim
    return P(*spec)


def caches_shardings(cache_shape_tree, mesh):
    from jax.sharding import NamedSharding

    def leaf(path, leaf):
        return NamedSharding(mesh, cache_spec(path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape_tree)


# ---------------------------------------------------------------------------
# activation sharding constraints (enabled only under a mesh; the model code
# calls ``constrain(x, kind)`` and it is a no-op in tests / CPU runs)
# ---------------------------------------------------------------------------

_ACTIVE_POLICY: Optional["ActivationPolicy"] = None


class ActivationPolicy:
    """Decides activation PartitionSpecs per tensor kind.

    kinds:
      residual — (B, S, d): batch over dp (seq over dp when B=1)
      heads    — (B, S, Hn, hd): batch over dp; heads over model when
                 divisible, else *sequence-TP* (S over model) — the fallback
                 for archs whose head counts don't divide the model axis
                 (qwen2's 14 heads, hymba's 25, on a 16-wide model axis).
      tokens   — (T, ...) flattened token-major tensors: T over dp
    """

    def __init__(self, mesh):
        self.mesh = mesh
        self.dp = dp_axes(mesh)
        self.ndp = axis_size(mesh, self.dp)
        self.ntp = mesh.shape["model"]

    KINDS = ("residual", "heads", "tokens", "loss_chunk", "moe_group")

    def spec(self, kind: str, shape: Tuple[int, ...]) -> Optional[P]:
        dp, ndp, ntp = self.dp, self.ndp, self.ntp
        s: list = [None] * len(shape)
        if kind == "residual" and len(shape) == 3:
            if _divisible(shape[0], ndp):
                s[0] = dp
            elif _divisible(shape[1], ndp):
                s[1] = dp
            # Megatron-style sequence parallelism: the residual stream (and
            # therefore every remat-boundary save) also shards its seq dim
            # over "model"; attention/collectives re-gather per layer.
            if s[1] is None and shape[1] > 1 and _divisible(shape[1], ntp):
                s[1] = "model"
            return P(*s)
        if kind == "heads" and len(shape) == 4:
            if _divisible(shape[0], ndp):
                s[0] = dp
            elif _divisible(shape[1], ndp):
                s[1] = dp
            if _divisible(shape[2], ntp):
                s[2] = "model"
            elif s[1] is None and _divisible(shape[1], ntp) and shape[1] > 1:
                s[1] = "model"          # sequence-TP fallback
            return P(*s)
        if kind == "kv_heads" and len(shape) == 4:
            # GQA k/v when q runs head-TP: batch over dp, REPLICATED over
            # model (kv-heads rarely divide it; repeat_kv re-shards to the
            # q heads locally).  A seq-TP fallback here would force a
            # reshard copy per layer ("involuntary full remat" warnings).
            if _divisible(shape[0], ndp):
                s[0] = dp
            if _divisible(shape[2], ntp):
                s[2] = "model"
            return P(*s)
        if kind == "tokens" and len(shape) >= 2:
            if _divisible(shape[0], ndp):
                s[0] = dp
            return P(*s)
        if kind == "loss_chunk" and len(shape) == 3:
            # (B, Sc, d): batch over dp, seq/d replicated (pre-head gather)
            if _divisible(shape[0], ndp):
                s[0] = dp
            return P(*s)
        if kind == "moe_weight" and len(shape) == 3:
            # explicit ZeRO gather point: (E, d, f) replicated over dp,
            # f stays on model
            if _divisible(shape[2], ntp):
                s[2] = "model"
            return P(*s)
        if kind == "moe_weight_row" and len(shape) == 3:
            # (E, f, d): f on model, d replicated (gathered over dp)
            if _divisible(shape[1], ntp):
                s[1] = "model"
            return P(*s)
        if kind == "moe_group" and len(shape) == 3:
            # (G, gs, d): groups over dp, tokens/d replicated
            if _divisible(shape[0], ndp):
                s[0] = dp
            return P(*s)
        return None


def head_tp_active(H: int) -> bool:
    """True when the activation policy will shard H heads over model."""
    pol = _ACTIVE_POLICY
    return pol is not None and H % pol.ntp == 0


def tp_padded_heads(H: int, KV: int) -> int:
    """Head count padded up to the model-axis multiple, when profitable.

    Zero-padded query heads make head-TP available to archs whose H doesn't
    divide the model axis (qwen2's 14, llama3.2's 24, qwen2.5's 40 on a
    16-wide axis) — exact math (padded wo rows are zero), ≤50% extra
    attention FLOPs, and it replaces the seq-TP fallback whose backward
    all-reduces dk/dv per chunk per layer (§Perf iteration 1).
    Constraints: padded H must stay a multiple of KV (GQA groups) and the
    overhead is capped at 1.5x.
    """
    pol = _ACTIVE_POLICY
    if pol is None or H % pol.ntp == 0:
        return H
    Hp = -(-H // pol.ntp) * pol.ntp
    if KV > 0 and Hp % KV != 0:
        return H
    if Hp > 1.5 * H:
        return H
    return Hp


def enable_activation_policy(mesh) -> None:
    global _ACTIVE_POLICY
    _ACTIVE_POLICY = ActivationPolicy(mesh) if mesh is not None else None


def constrain(x, kind: str):
    """Apply an activation sharding constraint when a policy is active."""
    pol = _ACTIVE_POLICY
    if pol is None:
        return x
    spec = pol.spec(kind, x.shape)
    if spec is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, spec))
